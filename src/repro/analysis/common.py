"""Shared infrastructure for the ``gnscheck`` static passes.

Everything here is plain-``ast`` and stdlib-only: the analyzer parses the
repo, it never imports it, so a broken or jax-less environment can still run
the checks (that is what lets CI put the pass *before* the test jobs).

Provided:

* :class:`Violation` — one finding, with a line-number-free :meth:`key` so
  the baseline survives unrelated edits (see ``baseline.py``).
* :class:`RepoIndex` — every module parsed once, parent links attached, with
  per-module import maps, function/class tables, and a cheap call graph
  (module functions, ``self.`` methods, direct imports, and a unique-name
  fallback for attribute calls).
* :func:`find_trace_roots` — the functions handed to ``jax.jit`` /
  ``shard_map`` / ``pallas_call``, with their static argument markers — the
  shared entry-point discovery for the trace-purity and retrace passes.
* ``# gnscheck: ignore[rule]`` line suppressions.
"""
from __future__ import annotations

import ast
import dataclasses
import re
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Set, Tuple

SUPPRESS_RE = re.compile(r"#\s*gnscheck:\s*ignore\[([a-z0-9_,\- ]+)\]")


@dataclasses.dataclass(frozen=True)
class Violation:
    rule: str
    path: str                # repo-relative, '/'-separated
    line: int
    symbol: str              # dotted qualname of the enclosing def/class
    message: str
    detail: str = ""         # stable discriminator (attr name, callee, ...)
    severity: str = "error"  # "error" | "warning"

    def key(self) -> str:
        """Line-number-free identity used by the baseline ratchet."""
        return f"{self.rule}|{self.path}|{self.symbol}|{self.detail}"

    def render(self) -> str:
        tag = "warning" if self.severity == "warning" else "error"
        return (f"{self.path}:{self.line}: [{self.rule}] {tag}: "
                f"{self.message} ({self.symbol})")


def attach_parents(tree: ast.AST) -> None:
    for node in ast.walk(tree):
        for child in ast.iter_child_nodes(node):
            child._gns_parent = node  # type: ignore[attr-defined]


def parents(node: ast.AST) -> Iterable[ast.AST]:
    cur = getattr(node, "_gns_parent", None)
    while cur is not None:
        yield cur
        cur = getattr(cur, "_gns_parent", None)


def dotted(node: ast.AST) -> Optional[str]:
    """``a.b.c`` for a Name/Attribute chain, else None."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


@dataclasses.dataclass
class FuncInfo:
    qualname: str            # "pkg.mod:Class.method" / "pkg.mod:fn.inner"
    node: ast.AST            # FunctionDef | AsyncFunctionDef | Lambda
    module: "ModuleInfo"
    cls: Optional[str]       # enclosing class name, if a method

    @property
    def name(self) -> str:
        return self.qualname.rsplit(".", 1)[-1].rsplit(":", 1)[-1]


@dataclasses.dataclass
class ModuleInfo:
    name: str                # dotted module name ("repro.featurestore.store")
    path: str                # repo-relative path
    tree: ast.Module
    source_lines: List[str]
    imports: Dict[str, str] = dataclasses.field(default_factory=dict)
                             # local alias -> dotted target ("np" -> "numpy",
                             # "jit" -> "jax.jit")
    functions: Dict[str, FuncInfo] = dataclasses.field(default_factory=dict)
                             # local qualname ("Class.method", "fn") -> info

    def suppressed(self, line: int) -> Set[str]:
        if 1 <= line <= len(self.source_lines):
            m = SUPPRESS_RE.search(self.source_lines[line - 1])
            if m:
                return {r.strip() for r in m.group(1).split(",")}
        return set()


class RepoIndex:
    """All modules under ``root`` parsed, indexed, and cross-linked."""

    def __init__(self, root: Path, package_prefix: Optional[str] = None):
        self.root = Path(root)
        self.modules: Dict[str, ModuleInfo] = {}      # dotted name -> info
        self.by_path: Dict[str, ModuleInfo] = {}
        # bare function/method name -> [qualified "mod:local" names]
        self.methods_by_name: Dict[str, List[str]] = {}
        prefix = package_prefix if package_prefix is not None \
            else self.root.name
        for py in sorted(self.root.rglob("*.py")):
            rel = py.relative_to(self.root)
            mod_name = ".".join((prefix, *rel.with_suffix("").parts)) \
                if str(rel) != "__init__.py" else prefix
            if rel.name == "__init__.py":
                mod_name = ".".join((prefix, *rel.parent.parts)) \
                    if rel.parent.parts else prefix
            try:
                src = py.read_text()
                tree = ast.parse(src)
            except (SyntaxError, UnicodeDecodeError):
                continue
            attach_parents(tree)
            mi = ModuleInfo(name=mod_name, path=str(rel).replace("\\", "/"),
                            tree=tree, source_lines=src.splitlines())
            self._index_imports(mi)
            self._index_functions(mi)
            self.modules[mod_name] = mi
            self.by_path[mi.path] = mi
        for mi in self.modules.values():
            for local, fi in mi.functions.items():
                self.methods_by_name.setdefault(fi.name, []).append(
                    f"{mi.name}:{local}")

    # ------------------------------------------------------------------
    @staticmethod
    def _index_imports(mi: ModuleInfo) -> None:
        for node in ast.walk(mi.tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    mi.imports[a.asname or a.name.split(".")[0]] = a.name
            elif isinstance(node, ast.ImportFrom) and node.module:
                for a in node.names:
                    if a.name == "*":
                        continue
                    mi.imports[a.asname or a.name] = \
                        f"{node.module}.{a.name}"

    def _index_functions(self, mi: ModuleInfo) -> None:
        def visit(node: ast.AST, scope: Tuple[str, ...],
                  cls: Optional[str]) -> None:
            for child in ast.iter_child_nodes(node):
                if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    local = ".".join((*scope, child.name))
                    mi.functions[local] = FuncInfo(
                        qualname=f"{mi.name}:{local}", node=child,
                        module=mi, cls=cls)
                    visit(child, (*scope, child.name), cls)
                elif isinstance(child, ast.ClassDef):
                    visit(child, (*scope, child.name), child.name)
                else:
                    visit(child, scope, cls)

        visit(mi.tree, (), None)

    # ------------------------------------------------------------------
    def resolve(self, mi: ModuleInfo, target: str) -> Optional[str]:
        """Resolve a dotted reference in ``mi``'s scope to "mod:local"."""
        head, _, rest = target.partition(".")
        # alias of an imported module / name
        imp = mi.imports.get(head)
        if imp is not None:
            target = f"{imp}.{rest}" if rest else imp
            # longest-prefix module match
            parts = target.split(".")
            for cut in range(len(parts) - 1, 0, -1):
                mod = ".".join(parts[:cut])
                if mod in self.modules:
                    local = ".".join(parts[cut:])
                    if local in self.modules[mod].functions:
                        return f"{mod}:{local}"
                    return None
            return None
        # module-local function (possibly Class.method)
        if target in mi.functions:
            return f"{mi.name}:{target}"
        return None

    def func(self, ref: str) -> Optional[FuncInfo]:
        mod, _, local = ref.partition(":")
        mi = self.modules.get(mod)
        return mi.functions.get(local) if mi else None

    # ------------------------------------------------------------------
    def callees(self, ref: str, unique_name_fallback: bool = False
                ) -> Set[str]:
        """Outgoing call/reference edges of one function (best effort).

        Catches direct calls, ``self.`` method calls, and bare *references*
        to repo functions (higher-order use: ``grad(loss_fn)``, thread
        targets, scan bodies).  With ``unique_name_fallback``, an attribute
        call on an unknown object resolves iff exactly one class in the repo
        defines that method name (over-approximation used by thread
        reachability, not by trace purity).
        """
        fi = self.func(ref)
        if fi is None:
            return set()
        mi = fi.module
        out: Set[str] = set()
        own_scope = ref.split(":", 1)[1]
        for node in ast.walk(fi.node):
            d = None
            if isinstance(node, (ast.Name, ast.Attribute)) \
                    and isinstance(getattr(node, "ctx", None), ast.Load):
                d = dotted(node)
            if not d:
                continue
            if d.startswith("self."):
                # method of the enclosing class
                meth = d[len("self."):]
                if "." in meth:
                    continue
                if fi.cls:
                    local = f"{fi.cls}.{meth}"
                    if local in mi.functions:
                        out.add(f"{mi.name}:{local}")
                continue
            r = self.resolve(mi, d)
            if r is not None and r != ref:
                out.add(r)
                continue
            if unique_name_fallback and "." in d:
                # over-approximate dynamic dispatch: a few same-named repo
                # methods (e.g. the policy registry's `scores`) all become
                # edges; a cap keeps pervasive names (`get`, `update`) from
                # connecting everything to everything
                name = d.rsplit(".", 1)[-1]
                cands = [c for c in self.methods_by_name.get(name, ())
                         if ":" in c and "." in c.split(":", 1)[1]]
                if 1 <= len(cands) <= 8:
                    out.update(cands)
        # nested defs are implicitly reachable from their parent (closures)
        for local, other in mi.functions.items():
            if local.startswith(own_scope + ".") and \
                    "." not in local[len(own_scope) + 1:]:
                out.add(f"{mi.name}:{local}")
        return out

    def reachable(self, roots: Iterable[str],
                  unique_name_fallback: bool = False) -> Set[str]:
        seen: Set[str] = set()
        stack = [r for r in roots]
        while stack:
            ref = stack.pop()
            if ref in seen:
                continue
            seen.add(ref)
            stack.extend(self.callees(
                ref, unique_name_fallback=unique_name_fallback))
        return seen


# ---------------------------------------------------------------------------
# traced-entry-point discovery (shared by trace_purity and retrace)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class TraceRoot:
    ref: str                     # "mod:local"
    kind: str                    # "jit" | "pallas" | "shard_map"
    site_path: str
    site_line: int
    static_names: Set[str] = dataclasses.field(default_factory=set)
    static_nums: Set[int] = dataclasses.field(default_factory=set)
    jit_call: Optional[ast.Call] = None   # the jax.jit(...) call, if any


def _is_jit_name(d: Optional[str], mi: ModuleInfo) -> bool:
    if d is None:
        return False
    if d in ("jax.jit", "jit"):
        tgt = mi.imports.get(d.split(".")[0], d)
        return tgt.startswith("jax") or d == "jax.jit"
    return False


def _const_set(node: ast.AST) -> Set:
    out = set()
    if isinstance(node, (ast.Tuple, ast.List, ast.Set)):
        for el in node.elts:
            if isinstance(el, ast.Constant):
                out.add(el.value)
    elif isinstance(node, ast.Constant):
        out.add(node.value)
    return out


def _extract_statics(call: ast.Call) -> Tuple[Set[str], Set[int]]:
    names: Set[str] = set()
    nums: Set[int] = set()
    for kw in call.keywords:
        if kw.arg == "static_argnames":
            names |= {v for v in _const_set(kw.value) if isinstance(v, str)}
        elif kw.arg == "static_argnums":
            nums |= {v for v in _const_set(kw.value) if isinstance(v, int)}
    return names, nums


def find_trace_roots(index: RepoIndex) -> List[TraceRoot]:
    """Every function handed to jit / pallas_call / shard_map, repo-wide."""
    roots: List[TraceRoot] = []
    seen: Set[Tuple[str, int]] = set()

    def _scope_of(node: ast.AST, mi: ModuleInfo) -> Optional[str]:
        """Local qualname ("Cls.meth.inner") of the enclosing function."""
        for p in parents(node):
            if isinstance(p, (ast.FunctionDef, ast.AsyncFunctionDef)):
                for local, fi in mi.functions.items():
                    if fi.node is p:
                        return local
        return None

    def add(mi: ModuleInfo, target: ast.AST, kind: str, line: int,
            statics: Tuple[Set[str], Set[int]] = (set(), set()),
            jit_call: Optional[ast.Call] = None) -> None:
        if isinstance(target, ast.Call):
            # jax.jit(make_step(...)): the traced function is the factory's
            # returned closure — treat the factory's directly nested defs as
            # roots (conservative: all of them)
            fd = dotted(target.func)
            if fd is None:
                return
            r = index.resolve(mi, fd)
            if r is None and fd in mi.functions:
                r = f"{mi.name}:{fd}"
            if r is None:
                return
            fmod, _, flocal = r.partition(":")
            fmi = index.modules.get(fmod)
            if fmi is None:
                return
            nested = [loc for loc in fmi.functions
                      if loc.startswith(flocal + ".")
                      and "." not in loc[len(flocal) + 1:]]
            for loc in (nested or [flocal]):
                k = (f"{fmod}:{loc}", line)
                if k not in seen:
                    seen.add(k)
                    roots.append(TraceRoot(
                        ref=f"{fmod}:{loc}", kind=kind, site_path=mi.path,
                        site_line=line, static_names=statics[0],
                        static_nums=statics[1], jit_call=jit_call))
            return
        d = dotted(target)
        if d is None:
            return
        if d.startswith("self."):
            # self-method handed to jit: resolve against every class that
            # defines it in this module
            meth = d[len("self."):]
            cands = [loc for loc in mi.functions
                     if loc.endswith("." + meth)]
            refs = [f"{mi.name}:{loc}" for loc in cands]
        else:
            r = index.resolve(mi, d)
            if r is None and "." not in d:
                # nested function referenced from its enclosing scope:
                # fn = shard_map_compat(body, ...) where `body` is a local def
                scope = _scope_of(target, mi)
                while scope is not None:
                    cand = f"{scope}.{d}"
                    if cand in mi.functions:
                        r = f"{mi.name}:{cand}"
                        break
                    scope = scope.rsplit(".", 1)[0] if "." in scope else None
                if r is None and scope is None:
                    # one-step local dataflow: `step = make_step(...); then
                    # jax.jit(step, ...)` — re-dispatch on the factory call
                    enc = _scope_of(target, mi)
                    fn_node = mi.functions[enc].node if enc else mi.tree
                    for st in ast.walk(fn_node):
                        if (isinstance(st, ast.Assign)
                                and isinstance(st.value, ast.Call)
                                and any(isinstance(t, ast.Name)
                                        and t.id == d
                                        for t in st.targets)):
                            add(mi, st.value, kind, line, statics, jit_call)
                        elif (isinstance(st, ast.Assign)
                              and isinstance(st.value, ast.IfExp)
                              and any(isinstance(t, ast.Name) and t.id == d
                                      for t in st.targets)):
                            for br in (st.value.body, st.value.orelse):
                                if isinstance(br, ast.Call):
                                    add(mi, br, kind, line, statics,
                                        jit_call)
            refs = [r] if r else []
        for ref in refs:
            k = (ref, line)
            if k in seen:
                continue
            seen.add(k)
            roots.append(TraceRoot(ref=ref, kind=kind, site_path=mi.path,
                                   site_line=line, static_names=statics[0],
                                   static_nums=statics[1],
                                   jit_call=jit_call))

    for mi in index.modules.values():
        for node in ast.walk(mi.tree):
            # decorators -----------------------------------------------------
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                for dec in node.decorator_list:
                    statics = (set(), set())
                    is_jit = False
                    jc = None
                    if _is_jit_name(dotted(dec), mi):
                        is_jit = True
                    elif isinstance(dec, ast.Call):
                        dd = dotted(dec.func)
                        if _is_jit_name(dd, mi):
                            is_jit, jc = True, dec
                            statics = _extract_statics(dec)
                        elif dd in ("functools.partial", "partial") \
                                and dec.args \
                                and _is_jit_name(dotted(dec.args[0]), mi):
                            is_jit, jc = True, dec
                            statics = _extract_statics(dec)
                    if is_jit:
                        # locate the decorated function in the table
                        for local, fi in mi.functions.items():
                            if fi.node is node:
                                k = (f"{mi.name}:{local}", node.lineno)
                                if k not in seen:
                                    seen.add(k)
                                    roots.append(TraceRoot(
                                        ref=f"{mi.name}:{local}", kind="jit",
                                        site_path=mi.path,
                                        site_line=node.lineno,
                                        static_names=statics[0],
                                        static_nums=statics[1],
                                        jit_call=jc))
            # call sites -----------------------------------------------------
            if not isinstance(node, ast.Call):
                continue
            d = dotted(node.func)
            if d is None:
                continue
            if _is_jit_name(d, mi) and node.args:
                add(mi, node.args[0], "jit", node.lineno,
                    _extract_statics(node), node)
            elif d.endswith("pallas_call") and node.args:
                add(mi, node.args[0], "pallas", node.lineno)
            elif d in ("shard_map", "shard_map_compat") \
                    or d.endswith(".shard_map"):
                if node.args:
                    add(mi, node.args[0], "shard_map", node.lineno)
    return roots

"""``gnscheck`` — repo-specific static analysis + runtime sanitizer.

Static passes (``python -m repro.analysis``): trace purity, lock
discipline, generation pinning, retrace hazards, plus a warning-tier
TrafficMeter-pairing lint.  Runtime half (imported by the annotated
subsystems): the ``@guarded_by`` registry and the debug-mode lock
sanitizer.

Only the runtime symbols are re-exported here — the annotated packages
(``featurestore``, ``serve``, ``core``) import this at module load, so it
must stay stdlib-only and must NOT pull the AST passes (or jax) in.
"""
from .runtime import (LockDisciplineError, LockOrderError, TrackedLock,
                      enable_sanitizer, guarded_by, holds_lock,
                      reset_lock_order, sanitizer_enabled)

__all__ = [
    "guarded_by", "holds_lock", "enable_sanitizer", "sanitizer_enabled",
    "reset_lock_order", "TrackedLock", "LockDisciplineError",
    "LockOrderError",
]

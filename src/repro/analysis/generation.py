"""Pass 3 — generation pinning.

The feature store's cache flips atomically between :class:`Generation`
objects; a mini-batch must be assembled against exactly ONE generation
(pinned in ``MiniBatch.cache_gen``) or its importance weights (paper
eq. 11) tear across the swap.  The safe idiom is a single snapshot read::

    gen = store.generation          # one atomic property read
    ...use gen.cache_table / gen.device_adj / gen.version...

Rules
-----
``gen-chained-read``
    ``store.generation.<field>`` — the generation object is read and
    dereferenced in one expression; a second such chain in the same scope
    may observe a different generation.
``gen-multi-read``
    two or more loads of ``<obj>.generation`` in one function body —
    each read may return a different generation.
``gen-direct-private``
    any touch of ``._live`` / ``._shadow`` / ``._staging_owner`` outside
    ``featurestore/store.py`` — the double-buffer internals are not API.

Whitelisted: ``featurestore/store.py`` itself, plus accessor functions
whose whole job is the pinned read (``adopt_generation``, ``ensure_cache``,
``serving``).
"""
from __future__ import annotations

import ast
from typing import Dict, List

from .common import RepoIndex, Violation, dotted, parents

PRIVATE_ATTRS = {"_live", "_shadow", "_staging_owner"}
WHITELIST_PATHS = {"repro/featurestore/store.py", "featurestore/store.py"}
WHITELIST_FUNCS = {"adopt_generation", "ensure_cache", "serving"}


def _enclosing_func_name(node: ast.AST) -> str:
    for p in parents(node):
        if isinstance(p, (ast.FunctionDef, ast.AsyncFunctionDef)):
            cls = None
            for q in parents(p):
                if isinstance(q, ast.ClassDef):
                    cls = q.name
                    break
            return f"{cls}.{p.name}" if cls else p.name
    return "<module>"


def run(index: RepoIndex) -> List[Violation]:
    out: List[Violation] = []
    for mi in index.modules.values():
        if mi.path in WHITELIST_PATHS or mi.path.endswith(
                "featurestore/store.py"):
            continue
        # per-function count of `X.generation` loads
        gen_reads: Dict[str, List[ast.Attribute]] = {}
        for node in ast.walk(mi.tree):
            if not isinstance(node, ast.Attribute):
                continue
            sym = _enclosing_func_name(node)
            if sym.split(".")[-1] in WHITELIST_FUNCS:
                continue
            sup = mi.suppressed(node.lineno)
            # --- private double-buffer internals --------------------------
            if node.attr in PRIVATE_ATTRS and isinstance(node.ctx,
                                                         (ast.Load,
                                                          ast.Store)):
                base = dotted(node.value)
                # only flag when the base smells like a store, to avoid
                # colliding with unrelated `_shadow` attrs in other classes
                if base is not None and ("store" in base.lower()
                                         or base == "self.store"):
                    if "gen-direct-private" not in sup and "*" not in sup:
                        out.append(Violation(
                            rule="gen-direct-private", path=mi.path,
                            line=node.lineno, symbol=sym,
                            message=(f"`{base}.{node.attr}` touches the "
                                     "store's double-buffer internals — use "
                                     "`generation` / `swap_if_ready()`"),
                            detail=f"{base}.{node.attr}"))
                continue
            if node.attr != "generation" or not isinstance(node.ctx,
                                                           ast.Load):
                continue
            # --- chained read: X.generation.Y -----------------------------
            parent = getattr(node, "_gns_parent", None)
            if isinstance(parent, ast.Attribute) and parent.value is node:
                if "gen-chained-read" not in sup and "*" not in sup:
                    base = dotted(node.value) or "<expr>"
                    out.append(Violation(
                        rule="gen-chained-read", path=mi.path,
                        line=node.lineno, symbol=sym,
                        message=(f"`{base}.generation.{parent.attr}` "
                                 "dereferences an unpinned generation — "
                                 "snapshot it first: `gen = "
                                 f"{base}.generation`"),
                        detail=f"{base}.generation.{parent.attr}"))
            # --- collect for multi-read (chained reads count too) ----------
            base = dotted(node.value)
            if base is None:
                continue
            key = f"{sym}|{base}"
            gen_reads.setdefault(key, []).append(node)
        for key, nodes in gen_reads.items():
            if len(nodes) < 2:
                continue
            sym, base = key.split("|", 1)
            first = nodes[1]  # report at the second read
            sup = mi.suppressed(first.lineno)
            if "gen-multi-read" in sup or "*" in sup:
                continue
            out.append(Violation(
                rule="gen-multi-read", path=mi.path, line=first.lineno,
                symbol=sym,
                message=(f"{len(nodes)} reads of `{base}.generation` in one "
                         "function — each may observe a different "
                         "generation; snapshot once"),
                detail=f"{base}.generation"))
    return out

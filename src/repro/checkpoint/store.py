"""Checkpoint store (deliverable: fault tolerance).

Design constraints at 1000+ nodes (DESIGN.md §4):

* **Atomic**: write to ``step_N.tmp/``, fsync, rename — a crash mid-write
  never corrupts the latest checkpoint; restart picks the newest complete one.
* **Self-describing**: a manifest (JSON) stores the pytree structure, leaf
  shapes/dtypes, and the *logical* step/epoch/RNG state — so a restarted job
  resumes bit-exact (GNS cache refresh RNG included).
* **Reshard-on-load (elastic)**: leaves are stored UNSHARDED (gathered);
  ``load_checkpoint`` places them under whatever sharding the *current* mesh
  prescribes — a 512-chip job resumes on 256 chips and vice versa.  At real
  pod scale one would write per-shard files + a reshard map; the single-file
  format keeps the same API and is what this container can exercise.
* **Keep-N**: bounded disk usage under periodic checkpointing.

Format: one ``.npz`` per checkpoint (numpy arrays, flattened tree paths as
keys) + ``manifest.json``.  No pickle — robust across refactors.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
from pathlib import Path
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree) -> dict[str, Any]:
    flat = {}

    def visit(kp, x):
        path = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in kp)
        flat[path] = x

    jax.tree_util.tree_map_with_path(visit, tree)
    return flat


def _unflatten_into(tree_like, flat: dict):
    def pick(kp, ref):
        path = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in kp)
        arr = flat[path]
        assert tuple(arr.shape) == tuple(ref.shape), (path, arr.shape, ref.shape)
        return arr

    return jax.tree_util.tree_map_with_path(pick, tree_like)


def save_checkpoint(directory: str | Path, step: int, tree,
                    extra: Optional[dict] = None, keep: int = 3,
                    aux: Optional[dict] = None) -> Path:
    """Atomically write ``tree`` (+ JSON-serializable ``extra``) as step N.

    ``aux`` is a flat ``{name: ndarray}`` side-payload stored OUTSIDE the
    pytree (its own ``aux.npz``): run-state whose shapes vary between saves
    — e.g. the streaming ingest's un-merged op log — and therefore cannot
    ride the fixed-shape ``_unflatten_into`` path.  Read it back with
    :func:`load_aux`.
    """
    directory = Path(directory)
    directory.mkdir(parents=True, exist_ok=True)
    flat = {k: np.asarray(v) for k, v in _flatten(tree).items()}
    aux = {k: np.asarray(v) for k, v in (aux or {}).items()}

    tmp = Path(tempfile.mkdtemp(dir=directory, prefix=f".step_{step}_"))
    try:
        with open(tmp / "arrays.npz", "wb") as f:
            np.savez(f, **flat)
            f.flush()
            os.fsync(f.fileno())
        if aux:
            with open(tmp / "aux.npz", "wb") as f:
                np.savez(f, **aux)
                f.flush()
                os.fsync(f.fileno())
        manifest = {
            "step": step,
            "extra": extra or {},
            "leaves": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                       for k, v in flat.items()},
            "aux": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                    for k, v in aux.items()},
        }
        with open(tmp / "manifest.json", "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        final = directory / f"step_{step:08d}"
        if final.exists():
            shutil.rmtree(final)
        os.rename(tmp, final)                      # atomic publish
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    _gc(directory, keep)
    return final


def _gc(directory: Path, keep: int):
    steps = sorted(p for p in directory.iterdir()
                   if p.is_dir() and p.name.startswith("step_"))
    for p in steps[:-keep] if keep else []:
        shutil.rmtree(p, ignore_errors=True)


def latest_step(directory: str | Path) -> Optional[int]:
    directory = Path(directory)
    if not directory.exists():
        return None
    steps = sorted(int(p.name.split("_")[1]) for p in directory.iterdir()
                   if p.is_dir() and p.name.startswith("step_")
                   and (p / "manifest.json").exists())
    return steps[-1] if steps else None


def load_checkpoint(directory: str | Path, tree_like,
                    step: Optional[int] = None,
                    shardings=None) -> tuple[Any, int, dict]:
    """Load into the structure of ``tree_like``; optionally device_put under
    ``shardings`` (reshard-on-load — the current mesh's prescription wins)."""
    directory = Path(directory)
    step = step if step is not None else latest_step(directory)
    assert step is not None, f"no checkpoint under {directory}"
    path = directory / f"step_{step:08d}"
    with open(path / "manifest.json") as f:
        manifest = json.load(f)
    with np.load(path / "arrays.npz") as z:
        flat = {k: z[k] for k in z.files}
    tree = _unflatten_into(tree_like, flat)
    if shardings is not None:
        tree = jax.tree_util.tree_map(
            lambda x, s: jax.device_put(x, s), tree, shardings)
    return tree, manifest["step"], manifest.get("extra", {})


def load_aux(directory: str | Path, step: Optional[int] = None) -> dict:
    """The ``aux`` side-payload of a checkpoint ({} when none was saved)."""
    directory = Path(directory)
    step = step if step is not None else latest_step(directory)
    assert step is not None, f"no checkpoint under {directory}"
    path = directory / f"step_{step:08d}" / "aux.npz"
    if not path.exists():
        return {}
    with np.load(path) as z:
        return {k: z[k] for k in z.files}


class CheckpointManager:
    """Periodic save + restart-resume driver used by the trainers."""

    def __init__(self, directory: str | Path, every: int = 100, keep: int = 3):
        self.directory = Path(directory)
        self.every = max(every, 1)
        self.keep = keep

    def maybe_save(self, step: int, tree, extra: Optional[dict] = None):
        if step % self.every == 0:
            return save_checkpoint(self.directory, step, tree, extra,
                                   keep=self.keep)
        return None

    def restore_or_init(self, tree_like, shardings=None):
        """(tree, start_step, extra) — from the newest checkpoint, else as-is."""
        if latest_step(self.directory) is None:
            return tree_like, 0, {}
        return load_checkpoint(self.directory, tree_like, shardings=shardings)

"""Fault-tolerant checkpointing: atomic, keep-N, reshard-on-load."""
from repro.checkpoint.store import (CheckpointManager, latest_step, load_aux,
                                    load_checkpoint, save_checkpoint)

__all__ = ["CheckpointManager", "save_checkpoint", "load_checkpoint",
           "load_aux", "latest_step"]

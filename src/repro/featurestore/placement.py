"""Locality-aware cache shard placement (Data Tiering, arXiv:2111.05894).

PR 2 row-partitioned the device cache table into *contiguous* slot blocks:
global slot ``s`` lives on shard ``s // rows_per_shard``.  That layout is
oblivious to *which* data-parallel group actually requests each cached row,
so on the production mesh every fused lookup pays a full psum over the cache
axis even when one shard could have served the whole batch.

This module turns the observed per-DP-group request histograms
(:class:`~repro.featurestore.meter.TrafficMeter`) into an explicit
slot -> (shard, local row) **permutation**:

* each DP group has a *home shard* on the cache axis
  (:func:`home_shard`, ``group % n_shards`` — the device a group's lookups
  can resolve without crossing the cache axis);
* :func:`solve_placement` assigns each cached row to the home shard of the
  group that requests it most — greedy hot-row-first under a hard
  ``rows_per_shard`` capacity per shard, deterministic under ``seed``
  (ties between equal-traffic rows are broken by a seeded shuffle, never by
  dict/argsort incidentals);
* :class:`PlacementMap` carries the resulting permutation both ways
  (``device_row_of_slot`` / ``slot_of_device_row``) so the store can upload
  each generation in device-row order and the fused kernel keeps seeing
  contiguous per-shard blocks — the kernel never learns about placement,
  only the slot values it is handed change.

:func:`identity_placement` reproduces PR 2's contiguous blocks exactly
(``device_row == slot``), which is also the fallback whenever no traffic has
been observed yet — so ``CacheConfig(placement="contiguous")`` and a cold
``"locality"`` store are bit-for-bit the PR 2 layout.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np


def home_shard(group: int, n_shards: int) -> int:
    """Cache-axis shard co-located with DP group ``group``.

    One rule for the solver, the store's locality metering and the trainer's
    fast-path decision — they must agree or "local" lanes would be counted
    against one shard and placed on another.
    """
    return int(group) % max(int(n_shards), 1)


@dataclasses.dataclass(frozen=True)
class PlacementMap:
    """Bijective slot -> (shard, local row) assignment for one generation.

    ``device_row_of_slot[s] == shard_of_slot[s] * rows_per_shard +
    local_row_of_slot[s]`` and ``slot_of_device_row`` is its inverse — both
    cover the full padded ``table_rows`` range, so every shard holds exactly
    ``rows_per_shard`` rows (padding slots included; padding rows carry zero
    traffic and are never handed to lookups by the store).
    """
    device_row_of_slot: np.ndarray     # int32 [table_rows]  slot -> table row
    slot_of_device_row: np.ndarray     # int32 [table_rows]  table row -> slot
    n_shards: int
    rows_per_shard: int

    @property
    def table_rows(self) -> int:
        return len(self.device_row_of_slot)

    @property
    def is_identity(self) -> bool:
        return bool(
            (self.device_row_of_slot ==
             np.arange(self.table_rows, dtype=np.int32)).all())

    def shard_of_slot(self, slots: np.ndarray) -> np.ndarray:
        slots = np.asarray(slots)
        dev = self.device_rows(slots)
        return np.where(dev >= 0, dev // self.rows_per_shard, -1)

    def local_row_of_slot(self, slots: np.ndarray) -> np.ndarray:
        slots = np.asarray(slots)
        dev = self.device_rows(slots)
        return np.where(dev >= 0, dev % self.rows_per_shard, -1)

    def device_rows(self, slots: np.ndarray) -> np.ndarray:
        """Map logical slots to device-table rows (-1 passes through)."""
        slots = np.asarray(slots)
        safe = np.clip(slots, 0, self.table_rows - 1)
        return np.where(slots >= 0, self.device_row_of_slot[safe],
                        -1).astype(np.int32)


def identity_placement(n_shards: int, table_rows: int) -> PlacementMap:
    """PR 2's contiguous blocks as an explicit permutation (the degenerate
    case every placement must decay to when traffic is uninformative)."""
    n_shards = max(int(n_shards), 1)
    assert table_rows % n_shards == 0, (table_rows, n_shards)
    eye = np.arange(table_rows, dtype=np.int32)
    return PlacementMap(device_row_of_slot=eye, slot_of_device_row=eye.copy(),
                        n_shards=n_shards,
                        rows_per_shard=table_rows // n_shards)


def _assign(total: np.ndarray, pref_shard: np.ndarray, n_shards: int,
            rows_per_shard: int,
            seed: int = 0,
            alt_prefs: Optional[np.ndarray] = None,
            pin_shard: Optional[np.ndarray] = None
            ) -> tuple[np.ndarray, np.ndarray]:
    """Greedy hot-row-first capacity assignment.

    Returns ``(shard_of, order)``: the shard index per slot, and the
    traffic-descending visit order it was assigned in (seeded shuffle breaks
    ties) — the caller derives local rows from the SAME order, so the
    tie-break lives in exactly one place.  Each row takes its preferred
    shard while that shard has capacity; rows spilled out of their first
    choice then try their ranked ``alt_prefs`` columns in traffic order
    (``-1`` entries are skipped) — the second-choice spill: a row that
    cannot live with its hottest group's home shard lands with its
    SECOND-hottest group's, capacity permitting, instead of whatever shard
    happens to have free capacity first.  Rows exhausting every ranked
    choice fall back to the remaining capacity in shard order, as before.
    Fully vectorized per pass (the dry-run solves paper-scale |C| ~ 1.1M
    rows; passes are bounded by ``alt_prefs`` columns).

    ``pin_shard`` (incremental re-solve, streaming ingest): rows with a
    non-negative entry claim THAT shard ahead of every preference pass —
    hot-first under the same capacity bound, overflow falls through to the
    normal passes.  ``None`` is bit-for-bit the original solve.
    """
    rows = len(total)
    assert rows == n_shards * rows_per_shard, (rows, n_shards, rows_per_shard)
    rng = np.random.default_rng(seed)
    tiebreak = rng.permutation(rows)
    order = np.lexsort((tiebreak, -np.asarray(total, dtype=np.float64)))

    pref = np.asarray(pref_shard, dtype=np.int64)[order]
    if pin_shard is not None and (np.asarray(pin_shard) >= 0).any():
        # pass 0 — pinned rows (unchanged since the last solve) keep their
        # shard, bounding the migration set to rows that actually changed
        pin = np.asarray(pin_shard, dtype=np.int64)[order]
        shard_ordered = np.full(rows, -1, dtype=np.int64)
        free = np.full(n_shards, rows_per_shard, dtype=np.int64)
        pr = np.where(pin >= 0)[0]
        cand = pin[pr]
        ok = _cumcount(cand, n_shards) < free[cand]
        shard_ordered[pr[ok]] = cand[ok]
        free -= np.bincount(cand[ok], minlength=n_shards)
        # first-choice pass for the remainder, against residual capacity
        un = np.where(shard_ordered < 0)[0]
        cand = pref[un]
        ok = _cumcount(cand, n_shards) < free[cand]
        shard_ordered[un[ok]] = cand[ok]
        free -= np.bincount(cand[ok], minlength=n_shards)
    else:
        # first-choice pass: the i-th row (in traffic order) wanting shard s
        # gets it iff fewer than rows_per_shard hotter rows already claimed s
        rank_in_pref = _cumcount(pref, n_shards)
        got_pref = rank_in_pref < rows_per_shard
        shard_ordered = np.where(got_pref, pref, -1)
        free = rows_per_shard - np.bincount(pref[got_pref],
                                            minlength=n_shards)

    # ranked-alternative passes: unassigned rows (still hot-first) contend
    # for their c-th choice against whatever capacity the earlier passes
    # left.  A choice equal to an already-full shard simply fails again.
    if alt_prefs is not None and len(alt_prefs):
        alts = np.asarray(alt_prefs, dtype=np.int64)[order]
        for c in range(alts.shape[1]):
            un = np.where((shard_ordered < 0) & (alts[:, c] >= 0))[0]
            if not len(un) or not free.any():
                break
            cand = alts[un, c]
            rank = _cumcount(cand, n_shards)
            ok = rank < free[cand]
            shard_ordered[un[ok]] = cand[ok]
            free -= np.bincount(cand[ok], minlength=n_shards)

    # final spill: leftover rows fill the remaining capacity shard-by-shard
    # in shard order — deterministic, and by construction the coldest
    # contenders for every shard they wanted
    un = shard_ordered < 0
    spill_slots = np.repeat(np.arange(n_shards), free)
    shard_ordered[un] = spill_slots
    shard_of = np.empty(rows, dtype=np.int64)
    shard_of[order] = shard_ordered
    return shard_of, order


def _cumcount(values: np.ndarray, n_values: int) -> np.ndarray:
    """Per-element running count of prior occurrences of the same value."""
    counts = np.zeros(len(values), dtype=np.int64)
    for v in range(n_values):
        m = values == v
        counts[m] = np.arange(int(m.sum()))
    return counts


def solve_placement(group_traffic: np.ndarray,
                    n_shards: int, rows_per_shard: int, *,
                    group_ids: Optional[Sequence[int]] = None,
                    seed: int = 0,
                    pin_shard: Optional[np.ndarray] = None) -> PlacementMap:
    """Balanced locality assignment from per-group slot request counts.

    Args:
      group_traffic: [n_groups, table_rows] request counts per (DP group,
        logical slot).  Padding slots must carry zero counts.
      n_shards / rows_per_shard: the device-table layout being filled.
      group_ids: actual DP group indices per histogram row (defaults to
        ``range(n_groups)``); a group's home shard is ``home_shard(g)``.
      seed: tie-break determinism (equal-traffic rows).

    Every slot's preferred shard is the home shard of the group that
    requests it most (ties -> lowest group id); a slot spilled out of its
    first choice tries the home shards of its remaining groups in traffic
    order (second-hottest first, zero-traffic groups never count as a
    choice) before falling back to first-free-in-shard-order — so overflow
    rows still land where SOME of their demand lives.  The greedy
    assignment is capacity-bounded so each shard ends with exactly
    ``rows_per_shard`` rows.  All-zero histograms decay to
    :func:`identity_placement`.

    ``pin_shard`` (int [table_rows], ``-1`` = free) pre-claims shards for
    unchanged rows — see :func:`solve_placement_incremental`.
    """
    traffic = np.asarray(group_traffic, dtype=np.float64)
    assert traffic.ndim == 2, traffic.shape
    n_groups, rows = traffic.shape
    assert rows == n_shards * rows_per_shard, (traffic.shape, n_shards,
                                               rows_per_shard)
    total = traffic.sum(axis=0)
    if n_groups == 0 or not (total > 0).any():
        return identity_placement(n_shards, rows)
    if group_ids is None:
        group_ids = np.arange(n_groups)
    homes = np.array([home_shard(g, n_shards) for g in group_ids],
                     dtype=np.int64)
    pref = homes[np.argmax(traffic, axis=0)]
    alt_prefs = None
    if n_groups > 1:
        # ranked alternatives: each row's remaining groups hottest-first
        # (stable sort -> ties break toward the lowest group id, matching
        # argmax above); a group with zero traffic for the row is no choice
        grp_order = np.argsort(-traffic, axis=0, kind="stable")   # [G, rows]
        ranked_homes = homes[grp_order]
        ranked_traffic = np.take_along_axis(traffic, grp_order, axis=0)
        alt_prefs = np.where(ranked_traffic[1:] > 0,
                             ranked_homes[1:], -1).T               # [rows, G-1]

    shard_of, order = _assign(total, pref, n_shards, rows_per_shard,
                              seed=seed, alt_prefs=alt_prefs,
                              pin_shard=pin_shard)
    # local rows: order of assignment within each shard (hot rows first),
    # derived from the SAME visit order the shards were assigned in
    local = np.empty(rows, dtype=np.int64)
    local[order] = _cumcount(shard_of[order], n_shards)
    dev = (shard_of * rows_per_shard + local).astype(np.int32)
    inv = np.empty(rows, dtype=np.int32)
    inv[dev] = np.arange(rows, dtype=np.int32)
    return PlacementMap(device_row_of_slot=dev, slot_of_device_row=inv,
                        n_shards=int(n_shards),
                        rows_per_shard=int(rows_per_shard))


def solve_placement_incremental(group_traffic: np.ndarray,
                                n_shards: int, rows_per_shard: int, *,
                                pin_shard: np.ndarray,
                                group_ids: Optional[Sequence[int]] = None,
                                seed: int = 0) -> PlacementMap:
    """Bounded-migration re-solve for streaming ingest.

    ``pin_shard[s]`` is the shard slot ``s``'s row held at the LAST solve
    when its demand signature (hottest group + degree) is unchanged since
    then, else ``-1``.  Pinned rows keep their shard (hot-first under the
    capacity bound — ties can spill a cold pinned row, keeping shards
    exactly balanced); only changed/new rows are re-assigned through the
    normal preference passes.  Because an unchanged row's previous shard
    was already the home shard of its hottest group, pinning preserves the
    locality the full solve achieved — ``route_local_fraction`` cannot
    regress beyond the changed set (CI-asserted in the stream smoke).
    """
    return solve_placement(group_traffic, n_shards, rows_per_shard,
                           group_ids=group_ids, seed=seed,
                           pin_shard=np.asarray(pin_shard, dtype=np.int64))


# ---------------------------------------------------------------------------
# serving-side routing table
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class RoutingTable:
    """Node -> owning cache shard, derived from one live generation.

    This is the placement solver's output re-indexed for a request router:
    ``shard_of_node[v]`` is the shard whose device-table block holds node
    ``v``'s cached row (``-1`` = not cached this generation).  A serving
    fabric sends each request to the worker whose home shard owns the most
    of its ids, so cross-shard gathers become cross-worker hops only on
    misses — the DGL dist-KV "route to the partition owner" shape, with the
    partition book coming from observed traffic instead of a static graph
    cut.
    """
    shard_of_node: np.ndarray   # int16 [num_nodes]; -1 = uncached
    n_shards: int
    version: int                # generation it was derived from

    @property
    def coverage(self) -> float:
        """Fraction of nodes with a known owner shard."""
        n = len(self.shard_of_node)
        return float((self.shard_of_node >= 0).sum()) / n if n else 0.0

    def owners(self, node_ids: np.ndarray) -> np.ndarray:
        """Owning shard per id (-1 where uncached)."""
        return self.shard_of_node[np.asarray(node_ids, dtype=np.int64)]


def routing_table_from_state(state, num_nodes: int) -> RoutingTable:
    """Build the router's view of one (live, un-retired) generation."""
    shard = np.full(int(num_nodes), -1, dtype=np.int16)
    size = len(state.node_ids)
    if size:
        slots = np.arange(size, dtype=np.int32)
        shard[state.node_ids] = state.shard_of(slots).astype(np.int16)
    return RoutingTable(shard_of_node=shard,
                        n_shards=max(int(state.n_shards), 1),
                        version=int(state.version))

"""Multi-tier feature store with pluggable cache policies + async refresh.

Subsumes the seed's ``core/cache.py`` (§3.2 cache sampling) and
``core/device_cache.py`` (device table upload) behind one facade with three
storage tiers:

  tier 0 — **device cache table** (``Generation.table``): |C| feature rows
           pinned on the accelerator, read inside the jitted step via
           ``h0 = where(slot >= 0, cache_table[slot], streamed)``.
  tier 1 — **pinned-host staging buffer** (``Generation.staged``): the host
           mirror the device table was uploaded from; serves host-side reads
           of cached rows without touching the big feature array.
  tier 2 — **host features** (``self.features``): the full [V, F] array;
           every read is metered as streamed bytes (the paper's §2.2 step 2).

Cache admission is delegated to a pluggable :class:`~.policies.CachePolicy`
(degree / random_walk / reverse_pagerank / adaptive / uniform — see
``policies.py``); the generation is drawn by Gumbel top-k without
replacement, exactly as the seed did.

**Double-buffered async refresh** (the paper's Table 6 staleness result makes
this accuracy-neutral): ``begin_refresh`` builds the *next* generation on a
background thread — policy scoring, Gumbel top-k draw, host gather into the
shadow staging buffer, device upload, and (for GNS) the induced cache
adjacency — while the train step keeps reading the live generation.
``swap_if_ready`` atomically publishes the shadow between steps.  Readers
always snapshot ``store.generation`` once per batch, so a batch's cache slots
and the table they index can never come from different generations.

**Shard-aware generations** (production mesh): with ``mesh`` + ``shard_axis``
the device table is row-partitioned into ``mesh.shape[shard_axis]``
blocks (padded via :attr:`CacheConfig.shards` so they divide evenly) and the
refresh uploads only each device's own shard — 1/n_shards of the replicated
transfer (``TrafficMeter.bytes_cache_upload``; see
benchmarks/bench_cache_sensitivity.run_sharded_upload).

**Locality-aware placement** (``CacheConfig(placement="locality")``): instead
of PR 2's arithmetic ``divmod(slot, rows_per_shard)`` blocks, each generation
carries an explicit slot -> (shard, local row) permutation
(:class:`CacheState.placement`, solved by
``featurestore.placement.solve_placement`` from the meter's per-DP-group
request histograms) that co-locates every cached row with the home shard of
the group that requests it most.  The staging tier stays in *logical* slot
order (host reads are placement-blind); only the device upload permutes into
device-row order, and ``assemble_input`` hands lookups **device rows**, so
the fused kernel keeps its contiguous per-shard view.  With
``placement="contiguous"`` (the default, and before any traffic is observed)
the permutation is the identity — bit-for-bit the PR 2 layout.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
import time
from typing import Optional, Sequence

import numpy as np

from repro.analysis import guarded_by
from repro.featurestore.meter import TrafficMeter
from repro.featurestore.placement import (PlacementMap, RoutingTable,
                                          home_shard, identity_placement,
                                          routing_table_from_state,
                                          solve_placement,
                                          solve_placement_incremental)
from repro.featurestore.policies import CachePolicy, make_policy


@dataclasses.dataclass(frozen=True)
class CacheConfig:
    fraction: float = 0.01          # |C| / |V|   (paper default 1%)
    period: int = 1                 # refresh every `period` epochs (Table 6 P)
    strategy: str = "auto"          # any registered policy name | auto
    train_frac_threshold: float = 0.5   # auto: degree if train_frac >= this
    walk_fanouts: Sequence[int] = (15, 10, 5)  # per-layer fanouts for eq. (7)
    async_refresh: bool = False     # build next generation on a background thread
    shards: int = 1                 # device-table row shards (mesh cache axis);
                                    # the table is padded so shards divide evenly
    placement: str = "contiguous"   # "contiguous" (PR 2 blocks, reproducible)
                                    # | "locality" (per-generation permutation
                                    # from observed per-DP-group traffic)
    refresh_timeout_s: Optional[float] = None
                                    # straggler bound for absorbing an
                                    # in-flight refresh (slow shard uploads):
                                    # None blocks as before; a float keeps
                                    # training on the old generation instead

    def size(self, num_nodes: int) -> int:
        """Device-table rows: |C| padded so `shards` rows-per-shard are equal."""
        rows = max(int(num_nodes * self.fraction), 1)
        rows += (-rows) % max(self.shards, 1)
        return rows


def resolve_strategy(cfg: CacheConfig, num_nodes: int,
                     train_idx: Optional[np.ndarray]) -> str:
    """'auto' -> degree for mostly-train graphs, random_walk for sparse V_S."""
    strategy = cfg.strategy
    if strategy == "auto":
        train_frac = 0.0 if train_idx is None else len(train_idx) / num_nodes
        strategy = "degree" if train_frac >= cfg.train_frac_threshold else "random_walk"
        if train_idx is None:
            strategy = "degree"
    return strategy


def cache_probs(g, cfg: CacheConfig,
                train_idx: Optional[np.ndarray] = None) -> np.ndarray:
    """One-shot §3.2 probabilities through the policy registry."""
    strategy = resolve_strategy(cfg, g.num_nodes, train_idx)
    policy = make_policy(strategy, walk_fanouts=cfg.walk_fanouts)
    policy.bind(g, train_idx)
    return policy.probs(g, train_idx)


@dataclasses.dataclass
class CacheState:
    """One sampled cache generation (versioned for async refresh at pod scale).

    **Shard-aware slot layout**: the device table holds ``table_rows`` rows
    partitioned into ``n_shards`` equal blocks — exactly how a
    ``NamedSharding(mesh, P(axis, None))`` splits the row dimension.  With
    ``placement=None`` (contiguous, the PR 2 layout) a global cache slot
    ``s`` lives on shard ``s // rows_per_shard`` at local row
    ``s % rows_per_shard``; a locality-aware generation instead carries an
    explicit :class:`~repro.featurestore.placement.PlacementMap` permutation.
    Samplers and the host-side tiers keep using *logical* slots; the device
    upload and anything handed to the device go through :meth:`device_rows`
    (identity when contiguous), and :meth:`shard_of` / :meth:`local_row`
    resolve the owning shard either way.
    """
    node_ids: np.ndarray        # int64 [|C|]  sorted
    probs: np.ndarray           # float64 [V]  the distribution it was drawn from
    in_cache: np.ndarray        # bool [V]
    slot_of: np.ndarray         # int32 [V]  position in node_ids or -1
    version: int = 0
    n_shards: int = 1           # row shards of the device table
    table_rows: int = 0         # padded device-table rows (0 = len(node_ids))
    placement: Optional[PlacementMap] = None
                                # slot -> (shard, local row) permutation;
                                # None = contiguous blocks (identity)

    @property
    def size(self) -> int:
        return len(self.node_ids)

    @property
    def rows_per_shard(self) -> int:
        rows = self.table_rows if self.table_rows else len(self.node_ids)
        return max(rows // max(self.n_shards, 1), 1)

    def device_rows(self, slots: np.ndarray) -> np.ndarray:
        """Logical slots -> device-table rows (negatives pass through).

        The device tier is laid out in *device-row* order: row
        ``shard * rows_per_shard + local_row``.  Contiguous generations are
        the identity; locality generations apply the placement permutation.
        Everything shipped to the device (``input_cache_slots``, the fused
        kernel's slot map) carries device rows, so the kernel's contiguous
        ``divmod`` stays valid whatever the placement.
        """
        slots = np.asarray(slots)
        if self.placement is None:
            return slots
        return self.placement.device_rows(slots)

    def shard_of(self, slots: np.ndarray) -> np.ndarray:
        """Shard index per global slot (negative slots stay negative)."""
        dev = self.device_rows(slots)
        return np.where(dev >= 0, dev // self.rows_per_shard, -1)

    def local_row(self, slots: np.ndarray) -> np.ndarray:
        """Row within the owning shard per global slot (-1 for misses)."""
        dev = self.device_rows(slots)
        return np.where(dev >= 0, dev % self.rows_per_shard, -1)


def sample_cache(g, cfg: CacheConfig, rng: np.random.Generator,
                 train_idx: Optional[np.ndarray] = None,
                 probs: Optional[np.ndarray] = None,
                 version: int = 0,
                 n_shards: Optional[int] = None,
                 table_rows: Optional[int] = None) -> CacheState:
    """Draw the cache without replacement according to the §3.2 distribution.

    ``n_shards`` / ``table_rows`` fix the shard layout of the device table
    the drawn ids will be uploaded into (defaults: the config's shard count
    and padded row count).  Fewer ids than rows is fine — the tail rows are
    zero-padded and no slot ever points at them.
    """
    if probs is None:
        probs = cache_probs(g, cfg, train_idx)
    if table_rows is None:
        table_rows = cfg.size(g.num_nodes)
    if n_shards is None:
        n_shards = max(cfg.shards, 1)
    assert table_rows % max(n_shards, 1) == 0, (
        f"table_rows={table_rows} must divide n_shards={n_shards} — pad via "
        f"CacheConfig(shards=...) / FeatureStore.padded_rows, otherwise "
        f"shard_of/local_row misroute the tail slots")
    size = min(table_rows, int((probs > 0).sum()))
    # Efficient weighted sampling w/o replacement: Gumbel top-k on log p.
    with np.errstate(divide="ignore"):
        logp = np.log(probs)
    gumbel = -np.log(-np.log(rng.random(g.num_nodes) + 1e-300) + 1e-300)
    keys = np.where(np.isfinite(logp), logp + gumbel, -np.inf)
    ids = np.sort(np.argpartition(keys, -size)[-size:].astype(np.int64))
    in_cache = np.zeros(g.num_nodes, dtype=bool)
    in_cache[ids] = True
    slot_of = np.full(g.num_nodes, -1, dtype=np.int32)
    slot_of[ids] = np.arange(size, dtype=np.int32)
    return CacheState(node_ids=ids, probs=probs, in_cache=in_cache,
                      slot_of=slot_of, version=version,
                      n_shards=n_shards, table_rows=table_rows)


@dataclasses.dataclass
class Generation:
    """One cache generation: membership + both storage tiers.

    ``state`` and ``table`` are immutable for the generation's whole
    lifetime (the device table is a fresh array per build), so a snapshot's
    slots always match its table.  ``staged`` aliases one half of the
    store's double buffer: when that half is recycled for a later build the
    store flips ``retired`` first, and staging reads fall back to the host
    tier — a stale handle can never serve another generation's rows.
    """
    state: CacheState
    table: object               # jax.Array [size, F] — device tier
    staged: np.ndarray          # f32 [size, F] pinned-host staging mirror
    staged_idx: int             # which double-buffer half `staged` is
    lam: Optional[float] = None  # calibrated inclusion λ (importance.py)
    cache_adj: object = None    # induced cached-neighbor CSR (GNS §3.3)
    device_adj: object = None   # repro.sampling.DeviceCacheAdj — the same
                                # CSR restricted to cached nodes as DEVICE
                                # arrays in device-row order (backend="device"
                                # sampling); rides the atomic swap with the
                                # table so structure and features publish
                                # together
    graph: object = None        # the CSRGraph this generation was built
                                # against (streaming ingest: a merge swaps
                                # the store's graph at a build boundary, and
                                # samplers adopt structure WITH the
                                # generation — pre-merge batches keep
                                # sampling the pre-merge graph)
    retired: bool = False       # staging half recycled by a newer build

    @property
    def version(self) -> int:
        return self.state.version

    def retire(self) -> None:
        """Mark stale and drop the O(V)/O(E_C) host references so queued
        MiniBatches holding this generation pin only the device table and
        the small membership id list, not ~GBs of per-node state at paper
        scale.  The sampler adopts each new generation long before its
        predecessor's staging half is recycled, so nothing reads these
        fields from a retired generation (gather_rows falls back to the
        host tier).  ``device_adj`` is KEPT: like the table it is
        device-resident (no O(V) host memory) and a queued batch replayed
        against this generation still needs its draw structure."""
        self.retired = True
        self.cache_adj = None
        self.graph = None     # samplers adopted long ago; don't pin O(E)
        self.state.probs = None
        self.state.in_cache = None
        self.state.slot_of = None


@guarded_by("_lock", "_shadow", "_thread", "_refresh_err",
            writes_only=("_live", "swaps", "refreshes",
                         "merges_applied", "rows_migrated"))
class FeatureStore:
    """Facade over the three feature tiers + the cache refresh lifecycle.

    Concurrency contract (machine-checked by ``gnscheck``): the refresh
    thread, the serving worker, and the training loop coordinate through
    ``_lock``.  ``_shadow``/``_thread``/``_refresh_err`` are read AND
    written under it; ``_live`` and the monotonic counters follow the
    publish/snapshot idiom — writes are locked so the reference swap and
    increments are atomic, while lock-free snapshot reads (the
    ``generation`` property, test assertions on ``swaps``) are the API.
    """

    def __init__(self, features: np.ndarray, graph, cfg: CacheConfig, *,
                 policy: Optional[CachePolicy] = None,
                 train_idx: Optional[np.ndarray] = None,
                 sharding=None, dtype=None,
                 mesh=None, shard_axis: Optional[str] = None,
                 meter: Optional[TrafficMeter] = None,
                 importance_mode: Optional[str] = "ht",
                 build_adjacency: bool = False,
                 dp_group: int = 0,
                 seed: int = 0):
        """``mesh`` + ``shard_axis`` turn on shard-aware generations: the
        device table is row-partitioned into ``mesh.shape[shard_axis]``
        contiguous blocks and each refresh uploads only each device's own
        shard (tables replicate along the remaining mesh axes).  The legacy
        ``sharding`` argument still accepts an explicit ``jax.sharding``
        for a plain ``device_put`` upload (replicated baseline)."""
        self.features = features
        self.graph = graph
        self.mesh = mesh
        if mesh is not None and shard_axis is None:
            # one home for the axis rule (lazy: featurestore stays jax-free
            # at import time)
            from repro.launch.mesh import cache_shard_axis
            shard_axis = cache_shard_axis(mesh)
        self.shard_axis = shard_axis
        n_shards = (mesh.shape[shard_axis] if mesh is not None
                    else max(cfg.shards, 1))
        if n_shards != cfg.shards:
            cfg = dataclasses.replace(cfg, shards=n_shards)
        self.n_shards = n_shards
        self.cfg = cfg
        self.train_idx = train_idx
        if policy is None:
            name = resolve_strategy(cfg, graph.num_nodes, train_idx)
            policy = make_policy(name, walk_fanouts=cfg.walk_fanouts)
        elif isinstance(policy, str):
            policy = make_policy(policy, walk_fanouts=cfg.walk_fanouts)
        self.policy = policy
        self.policy.bind(graph, train_idx)
        self.meter = meter if meter is not None else TrafficMeter()
        self.sharding = sharding
        self.dtype = dtype
        self.importance_mode = importance_mode
        self.build_adjacency = build_adjacency
        self.build_device_adj = False   # also materialize the device-row
                                        # cache_adj CSR per generation
                                        # (backend="device" sampling; set by
                                        # DeviceGNSSampler before first build)
        self.size = cfg.size(graph.num_nodes)
        self.feat_dim = features.shape[1]
        self._row_bytes = self.feat_dim * 4
        self.dp_group = dp_group    # DP group this store's batches belong to
                                    # (assemble_input default; locality
                                    # histograms and home-shard metering)

        # double-buffered pinned-host staging (tier 1): live half + shadow half
        self._staging = [np.zeros((self.size, self.feat_dim), np.float32)
                         for _ in range(2)]
        self._staging_owner: list = [None, None]   # Generation using each half
        self._live: Optional[Generation] = None
        self._shadow: Optional[Generation] = None
        self._lock = threading.Lock()
        self._thread: Optional[threading.Thread] = None
        self._refresh_err: Optional[BaseException] = None
        self._static_probs: Optional[np.ndarray] = None
        self._lam_cache: Optional[tuple] = None
        self._rng = np.random.default_rng(seed)
        self.refreshes = 0
        self.swaps = 0
        # --- streaming ingest (attach_stream) ----------------------------
        self.labels: Optional[np.ndarray] = None
                                    # host label array, grown alongside
                                    # `features` at merges (set by the engine;
                                    # plain ref-swap like `features`)
        self._stream = None         # DeltaBuffer | None — staged mutations
        self.stream_cfg = None      # StreamConfig | None
        self._merge_listeners: list = []
        self._placement_sig: Optional[dict] = None
                                    # previous solve's per-row demand
                                    # signature (incremental re-solve pins)
        self.merges_applied = 0
        self.rows_migrated = 0      # rows the incremental re-solve moved
        self.record = True          # False: suspend meter + policy feedback
                                    # (evaluation must not skew training
                                    # metrics or the adaptive traffic EMA)
        self.serve_meter: Optional[TrafficMeter] = None
                                    # serving mode (record=False + a meter
                                    # here, via ``serving()``): tier/time
                                    # accounting lands on THIS meter while
                                    # policy/placement feedback stays live —
                                    # serving traffic steers the cache
                                    # without touching training metrics
        self.refresh_delay = 0.0    # test hook: artificial build latency (s)
        self.upload_delay = 0.0     # test hook: artificial shard-upload
                                    # latency (s) — the straggler the
                                    # refresh_timeout_s path must absorb

    # ------------------------------------------------------------------
    # generation access (readers snapshot once per batch)
    # ------------------------------------------------------------------
    @property
    def generation(self) -> Optional[Generation]:
        """The live generation.  Snapshot it once and use only the snapshot:
        the (state, table) pair inside one Generation is immutable, so a
        reader can never see slots from one version and rows from another."""
        return self._live

    @property
    def state(self) -> Optional[CacheState]:
        gen = self._live
        return gen.state if gen is not None else None

    @property
    def version(self) -> int:
        gen = self._live
        return gen.version if gen is not None else -1

    @property
    def refreshing(self) -> bool:
        with self._lock:
            t = self._thread
        return t is not None and t.is_alive()

    def routing_table(self) -> Optional["RoutingTable"]:
        """Node -> owning-shard view of the LIVE generation (None pre-build).

        Derived from the live ``CacheState`` (whose ``slot_of`` is intact —
        only retired generations drop it), so a serving router can re-adopt
        it at every swap: the placement solver moves rows toward the DP
        group that requests them, and this table is how the router learns
        where they went.
        """
        gen = self._live
        if gen is None:
            return None
        return routing_table_from_state(gen.state, self.graph.num_nodes)

    # ------------------------------------------------------------------
    # accounting modes
    # ------------------------------------------------------------------
    @contextlib.contextmanager
    def serving(self, meter: TrafficMeter):
        """Serving-mode accounting scope (the GNSServer's sampling window).

        Inside the scope, ``assemble_input`` routes its tier/time/locality
        counters to ``meter`` — a serving-side :class:`TrafficMeter` view —
        instead of the training meter, while the adaptive policy's EMA and
        the placement demand histograms KEEP observing: serving traffic must
        steer cache admission and shard placement (the cache converges onto
        the inference hot set) without inflating training metrics.  Contrast
        ``record = False`` alone (evaluation), which suspends everything.

        Not safe to interleave with a concurrent ``fit``/``evaluate`` on the
        same store — one accounting mode at a time (the serving loop holds
        the scope only while it samples, on its single worker thread).
        """
        prev_record, prev_meter = self.record, self.serve_meter
        self.record, self.serve_meter = False, meter
        try:
            yield self
        finally:
            self.record, self.serve_meter = prev_record, prev_meter

    # ------------------------------------------------------------------
    # tier reads
    # ------------------------------------------------------------------
    def assemble_input(self, gen: Generation, ids_p: np.ndarray, n_in: int,
                       group: Optional[int] = None):
        """Resolve padded input ids against one generation.

        Returns ``(slots, streamed, num_cached, bytes_streamed,
        local_shard)``.  ``slots`` are **device rows** (the table is laid
        out in device-row order — identical to logical slots for contiguous
        generations); hits are served by the device table (tier 0, counted
        but not copied); misses are gathered from host features (tier 2)
        into the per-batch streamed array and fed back to the policy.

        ``local_shard`` is the requesting group's home shard when EVERY hit
        row of this batch lives on it (else None) — the host-side gate for
        the fused kernel's psum-free fast path (``kernels.ops
        .cache_lookup_agg(local_shard=...)``): the contract that all hit
        lanes resolve locally is established here, where the slot map is
        built, and nowhere else.
        """
        if group is None:
            group = self.dp_group
        state = gen.state
        slots = state.device_rows(state.slot_of[ids_p]).astype(np.int32)
        slots[n_in:] = -1
        valid = np.zeros(len(ids_p), dtype=bool)
        valid[:n_in] = True
        miss = (slots < 0) & valid
        hits = int(((slots >= 0) & valid).sum())
        t0 = time.perf_counter()
        streamed = np.zeros((len(ids_p), self.feat_dim), np.float32)
        miss_ids = ids_p[miss]
        if len(miss_ids):
            streamed[miss] = self.features[miss_ids]
        # locality: which shard serves each hit, vs the group's home shard
        home = home_shard(group, state.n_shards)
        hit_shards = slots[(slots >= 0) & valid] // state.rows_per_shard
        n_local = int((hit_shards == home).sum())
        all_local = state.n_shards > 1 and n_local == len(hit_shards)
        # accounting sink for this mode: the training meter, the serving
        # meter (``serving()`` scope), or nothing (evaluation)
        meter = self.meter if self.record else self.serve_meter
        if meter is not None:
            meter.t_slice += time.perf_counter() - t0
            dev = meter.tier("device")
            dev.hits += hits
            dev.misses += len(miss_ids)
            dev.bytes_read += hits * self._row_bytes
            host = meter.tier("host")
            host.hits += len(miss_ids)
            host.bytes_read += len(miss_ids) * self._row_bytes
            meter.lanes_local += n_local
            meter.lanes_remote += hits - n_local
            meter.bytes_cross_shard += (hits - n_local) * self._row_bytes
            if self.cfg.placement == "locality":
                # per-group demand histogram: the placement solver's input.
                # ALWAYS on the training meter — the solver reads exactly
                # one demand signal, and serving traffic must steer the
                # next generation's placement too.
                self.meter.observe_group(group, ids_p[:n_in],
                                         self.graph.num_nodes)
            # feed the FULL requested-id traffic (hits AND misses) to the
            # policy: a miss-only feed starves the EMA of nodes once they
            # become hits, so their scores decay until eviction and they
            # oscillate in and out of the cache (ROADMAP follow-up; see
            # AdaptivePolicy and the churn regression test).
            self.policy.observe(ids_p[:n_in])
        return (slots, streamed, hits, len(miss_ids) * self._row_bytes,
                home if all_local else None)

    def gather_rows(self, ids: np.ndarray,
                    gen: Optional[Generation] = None,
                    record: Optional[bool] = None) -> np.ndarray:
        """Host-side row gather through the tier hierarchy.

        Rows present in the generation are served from the pinned staging
        buffer (tier 1); the rest fall through to the host features (tier 2).
        This is the refresh path's row source (``_build`` seeds each new
        generation from the live generation's staging mirror, so rows kept
        across generations never touch the big feature array) and the
        public API for host-side reads.  ``record=None`` inherits the
        store's accounting flag.
        """
        if record is None:
            record = self.record
        ids = np.asarray(ids, dtype=np.int64)
        rows = np.empty((len(ids), self.feat_dim), np.float32)
        rest = np.ones(len(ids), dtype=bool)
        if gen is None:
            gen = self._live
        # capture the slot map before the retired check: retire() drops it,
        # and holding our own reference keeps the array alive mid-read
        sl_map = gen.state.slot_of if gen is not None else None
        if gen is not None and not gen.retired and sl_map is not None:
            # ids past the map are nodes merged in AFTER this generation was
            # drawn (streaming ingest): pure misses, served by the host tier
            sl = np.full(len(ids), -1, dtype=sl_map.dtype)
            known = ids < len(sl_map)
            sl[known] = sl_map[ids[known]]
            hit = sl >= 0
            rows[hit] = gen.staged[sl[hit]]
            if gen.retired:
                # builder recycled this half mid-read (it flips the flag
                # BEFORE writing): discard and fall through to the host tier
                rest = np.ones(len(ids), dtype=bool)
            else:
                if record:
                    st = self.meter.tier("staging")
                    st.hits += int(hit.sum())
                    st.misses += int((~hit).sum())
                    st.bytes_read += int(hit.sum()) * self._row_bytes
                rest = ~hit
        n_rest = int(rest.sum())
        if n_rest:
            rows[rest] = self.features[ids[rest]]
            if record:
                host = self.meter.tier("host")
                host.hits += n_rest
                host.bytes_read += n_rest * self._row_bytes
        return rows

    # ------------------------------------------------------------------
    # refresh lifecycle
    # ------------------------------------------------------------------
    def _policy_probs(self) -> np.ndarray:
        if not self.policy.stateful:
            if self._static_probs is None:
                self._static_probs = self.policy.probs(self.graph, self.train_idx)
            return self._static_probs
        return self.policy.probs(self.graph, self.train_idx)

    def _solve_lambda(self, probs: np.ndarray) -> Optional[float]:
        if self.importance_mode != "ht":
            return None
        if self._lam_cache is not None and self._lam_cache[0] is probs:
            return self._lam_cache[1]
        from repro.core.importance import solve_inclusion_lambda
        lam = solve_inclusion_lambda(probs, self.size)
        self._lam_cache = (probs, lam)
        return lam

    def _solve_placement(self, state: CacheState,
                         rng: np.random.Generator,
                         graph=None) -> Optional[PlacementMap]:
        """Locality placement for one generation (None = stay contiguous).

        Uses the meter's per-DP-group request histograms restricted to the
        drawn membership; until any traffic is observed (cold start, or a
        store whose batches never went through ``assemble_input``) the
        layout stays contiguous, so reproducibility-sensitive runs get the
        PR 2 blocks for free.

        Streaming stores (``attach_stream`` with ``incremental_placement``)
        re-solve **incrementally**: every row whose demand signature
        (hottest requesting group + degree) is unchanged since the previous
        solve keeps its shard via the solver's pin pass, so only rows the
        ingest actually touched migrate — bounded migration per merge, and
        the serving router's local fraction cannot collapse on a swap.
        """
        if self.cfg.placement != "locality" or self.n_shards <= 1:
            return None
        traffic = self.meter.group_slot_traffic(state.node_ids,
                                                state.table_rows)
        if traffic is None:
            return None
        if graph is None:
            graph = self.graph
        seed = int(rng.integers(2 ** 31))
        gids = list(self.meter.group_ids())
        node_ids = np.asarray(state.node_ids, dtype=np.int64)
        n = len(node_ids)
        # per-slot demand signature: hottest group (-1 when untouched) + degree
        total = traffic.sum(axis=0)
        hot = np.asarray(gids, dtype=np.int64)[np.argmax(traffic, axis=0)]
        hot = np.where(total > 0, hot, -1)[:n]
        deg = np.asarray(graph.degrees)[node_ids].astype(np.int64)
        prev = self._placement_sig
        scfg = self.stream_cfg
        pin = None
        if (prev is not None and len(prev["node_ids"])
                and scfg is not None and scfg.incremental_placement):
            pos = np.searchsorted(prev["node_ids"], node_ids)
            pos = np.clip(pos, 0, len(prev["node_ids"]) - 1)
            common = prev["node_ids"][pos] == node_ids
            same = common & (prev["hot"][pos] == hot) \
                & (prev["degree"][pos] == deg)
            pin = np.full(state.table_rows, -1, dtype=np.int64)
            pin[:n][same] = prev["shard"][pos[same]]
        if pin is not None and (pin >= 0).any():
            pm = solve_placement_incremental(
                traffic, self.n_shards, state.rows_per_shard,
                pin_shard=pin, group_ids=gids, seed=seed)
        else:
            pm = solve_placement(traffic, self.n_shards,
                                 state.rows_per_shard,
                                 group_ids=gids, seed=seed)
        new_shard = (np.asarray(pm.device_row_of_slot[:n], dtype=np.int64)
                     // state.rows_per_shard)
        if prev is not None and len(prev["node_ids"]):
            pos = np.searchsorted(prev["node_ids"], node_ids)
            pos = np.clip(pos, 0, len(prev["node_ids"]) - 1)
            common = prev["node_ids"][pos] == node_ids
            moved = int((new_shard[common]
                         != prev["shard"][pos[common]]).sum())
            if moved:
                with self._lock:
                    self.rows_migrated += moved
        order = np.argsort(node_ids, kind="stable")
        self._placement_sig = {"node_ids": node_ids[order],
                               "shard": new_shard[order],
                               "hot": hot[order],
                               "degree": deg[order]}
        return pm

    # ------------------------------------------------------------------
    # streaming ingest (repro.stream)
    # ------------------------------------------------------------------
    def attach_stream(self, buffer, cfg=None) -> None:
        """Wire a :class:`repro.stream.DeltaBuffer` into the refresh cycle.

        Producers stage mutations into ``buffer`` at any time; every
        subsequent generation build drains it FIRST (``_absorb_deltas``), so
        structure changes only ever publish through the atomic swap and
        in-flight batches pinned to older generations replay bitwise
        identically.  Set once, before serving starts.
        """
        from repro.gns.config import StreamConfig
        self._stream = buffer
        self.stream_cfg = cfg if cfg is not None else StreamConfig()

    def add_merge_listener(self, cb) -> None:
        """``cb(store, batch)`` runs on the builder thread right after a
        drained :class:`DeltaBatch` is folded into the host tiers (the
        engine uses this to keep its dataset view in sync)."""
        self._merge_listeners.append(cb)

    def pending_deltas(self) -> int:
        """Ops staged in the attached stream buffer (0 when none attached)."""
        buf = self._stream
        return buf.pending() if buf is not None else 0

    def stream_merge_due(self) -> bool:
        """True when enough deltas are staged to justify kicking a refresh
        (the fabric watchdog's drain trigger)."""
        cfg = self.stream_cfg
        if self._stream is None or cfg is None:
            return False
        return self.pending_deltas() >= max(int(cfg.merge_min_pending), 1)

    def _absorb_deltas(self) -> bool:
        """Drain the stream buffer and fold it into the host tiers.

        Runs at the top of ``_build`` — generation builds are serialized
        (``begin_refresh`` single-flight + ``refresh`` absorbing in-flight
        builds), so this is the ONLY writer of ``graph``/``features``/
        ``labels``, and each is republished by a single reference swap
        (features strictly before graph: any reader that can see post-merge
        node ids must also see their feature rows).  Pre-merge readers keep
        their own refs via the pinned generation and never observe the swap.
        """
        buf = self._stream
        if buf is None or buf.pending() == 0:
            return False
        batch = buf.drain()
        if batch is None:
            return False
        # lazy import: keeps featurestore <-> stream from importing cyclically
        from repro.stream.merge import merge_delta_csr
        cfg = self.stream_cfg
        sym = cfg.symmetrize if cfg is not None else True
        new_graph = merge_delta_csr(self.graph, batch, symmetrize=sym)
        feats = self.features
        if batch.num_new_nodes:
            feats = np.concatenate(
                [np.asarray(self.features),
                 batch.node_feats.astype(np.float32)])
            if self.labels is not None:
                lbl = (batch.node_labels if batch.node_labels is not None
                       else np.zeros(batch.num_new_nodes, np.int64))
                self.labels = np.concatenate(
                    [self.labels, lbl.astype(self.labels.dtype)])
        self.features = feats           # features BEFORE graph (see above)
        self.graph = new_graph
        self.policy.bind(new_graph, self.train_idx)
        # structure changed: every cached score/λ is stale
        self._static_probs = None
        self._lam_cache = None
        self.meter.bytes_delta_upload += batch.payload_bytes
        with self._lock:
            self.merges_applied += 1
        for cb in list(self._merge_listeners):
            cb(self, batch)
        return True

    def _build(self, rng: np.random.Generator, version: int,
               staged_idx: int) -> Generation:
        """Build one full generation: score → draw → place → gather → upload."""
        t0 = time.perf_counter()
        self._absorb_deltas()
        g = self.graph      # ONE snapshot: everything this generation carries
                            # (membership, probs, adjacency, routing) must
                            # come from the same structure
        probs = self._policy_probs()
        state = sample_cache(g, self.cfg, rng,
                             train_idx=self.train_idx, probs=probs,
                             version=version,
                             n_shards=self.n_shards, table_rows=self.size)
        state.placement = self._solve_placement(state, rng, graph=g)
        # recycle this staging half: retire its previous owner BEFORE writing
        # so stale snapshots fall back to the host tier instead of reading
        # another generation's rows (see gather_rows)
        prev = self._staging_owner[staged_idx]
        if prev is not None:
            prev.retire()
        buf = self._staging[staged_idx]
        n = state.size
        # seed the new generation through the tier hierarchy: rows that
        # survive from the live generation come out of its staging mirror
        # (tier 1, cheap sequential reads), only the delta touches the big
        # feature array — unmetered (bytes_cache_fill is the refresh metric)
        buf[:n] = self.gather_rows(state.node_ids, gen=self._live,
                                   record=False)
        if n < self.size:
            buf[n:] = 0.0
        if self.refresh_delay:
            time.sleep(self.refresh_delay)            # test hook
        tbl = self._upload(buf, state)
        lam = self._solve_lambda(probs)
        adj = (g.induced_cache_adjacency(state.in_cache)
               if self.build_adjacency else None)
        dev_adj = None
        if self.build_device_adj and adj is not None:
            # lazy import: featurestore stays jax-free until a device
            # generation is actually built
            from repro.sampling.adjacency import build_device_cache_adj
            dev_adj = build_device_cache_adj(state, adj, g.degrees,
                                             lam=lam, meter=self.meter)
        gen = Generation(state=state, table=tbl, staged=buf,
                         staged_idx=staged_idx, lam=lam, cache_adj=adj,
                         device_adj=dev_adj, graph=g)
        self._staging_owner[staged_idx] = gen
        self.meter.bytes_cache_fill += n * self._row_bytes
        self.meter.t_refresh += time.perf_counter() - t0
        with self._lock:      # builder thread + owner thread both count
            self.refreshes += 1
        return gen

    def _upload(self, buf: np.ndarray, state: Optional[CacheState] = None):
        """Staging half -> device table (tier 0), metering the transfer.

        The staging tier keeps *logical* slot order; the device table is
        laid out in **device-row** order (``state.placement`` permutes on
        the way up — identity for contiguous generations), so shard ``s``'s
        block holds exactly the rows the placement assigned it.

        Shard-aware path (``mesh`` + ``shard_axis``): the table is
        row-partitioned over the cache axis and each device receives ONLY its
        own shard via ``make_array_from_callback`` — per generation that is
        ``table_bytes · ndev / n_shards`` on the wire instead of the
        replicated ``table_bytes · ndev``.  The callback hands jax a fresh
        contiguous copy of each shard slice (never a view of the staging
        half), and the upload is synchronized before the generation is
        published, so recycling the staging buffer for a later build can
        never mutate this generation's device tier (see the swap-race audit
        in tests/test_sharded_store.py).
        """
        import jax
        import jax.numpy as jnp

        pm = state.placement if state is not None else None
        if pm is not None and not pm.is_identity:
            buf = buf[pm.slot_of_device_row]       # fresh permuted copy
        if self.upload_delay:
            time.sleep(self.upload_delay)          # test hook: slow upload
        dtype = self.dtype or jnp.float32
        if self.mesh is not None:
            from jax.sharding import NamedSharding, PartitionSpec as P
            src = np.asarray(buf, dtype=np.dtype(dtype))
            sh = NamedSharding(self.mesh, P(self.shard_axis, None))
            # explicit per-shard copy: a contiguous row-slice of `src` is a
            # VIEW of the staging half, and device_put may zero-copy aligned
            # host buffers on CPU — either would alias the "immutable" device
            # tier to a buffer a later build recycles
            tbl = jax.make_array_from_callback(
                buf.shape, sh, lambda index: np.array(src[index], copy=True))
            tbl.block_until_ready()
        else:
            # jnp.array (copy=True) — asarray zero-copies aligned host buffers
            # on CPU, which would alias the table to the recycled staging half
            # and mutate an older generation's "immutable" device tier on reuse
            tbl = jnp.array(buf, dtype=dtype)
            if self.sharding is not None:
                tbl = jax.device_put(tbl, self.sharding)
                tbl.block_until_ready()
        try:
            upload = sum(int(s.data.nbytes) for s in tbl.addressable_shards)
        except Exception:                    # non-jax table stub in tests
            upload = int(getattr(tbl, "nbytes", 0))
        self.meter.bytes_cache_upload += upload
        self.meter.uploads += 1
        return tbl

    def _free_staging_idx(self) -> int:
        live = self._live
        return 1 - live.staged_idx if live is not None else 0

    def refresh(self, rng: Optional[np.random.Generator] = None,
                version: int = 0) -> Generation:
        """Synchronous refresh: build and immediately publish as live."""
        if rng is None:
            rng = self._rng
        with self._lock:
            t = self._thread
            pending = (t is not None and t.is_alive()) \
                or self._shadow is not None
        if pending:
            # absorb any in-flight async build first — two concurrent builds
            # would interleave writes into the same staging half
            self.wait_refresh()
        gen = self._build(rng, version, self._free_staging_idx())
        with self._lock:
            self._live = gen
            self._shadow = None
            self.swaps += 1
        return gen

    def begin_refresh(self, rng: Optional[np.random.Generator] = None,
                      version: int = 0) -> bool:
        """Kick an async build of the next generation (shadow).  Returns False
        if a refresh is already in flight or awaiting swap."""
        child = None
        staged_idx = 0

        def _run():
            try:
                gen = self._build(child, version, staged_idx)
                with self._lock:
                    self._shadow = gen
            except BaseException as e:   # surfaced at the next swap point
                with self._lock:
                    self._refresh_err = e

        t = threading.Thread(target=_run, daemon=True,
                             name="featurestore-refresh")
        # one locked region from the pending-check through t.start(): the
        # old check-then-start window let two callers both see "idle" and
        # interleave builds into the same staging half, and a concurrent
        # wait_refresh must never see a created-but-unstarted thread
        with self._lock:
            cur = self._thread
            if (cur is not None and cur.is_alive()) \
                    or self._shadow is not None:
                return False
            # derive an independent child rng NOW (in the caller's thread,
            # and only on the path that actually starts a build, so a False
            # return never perturbs the caller's stream) so the caller's
            # stream is never mutated concurrently by the builder
            seed = (rng if rng is not None else self._rng).integers(
                0, 2**63 - 1)
            child = np.random.default_rng(seed)
            staged_idx = self._free_staging_idx()
            self._thread = t
            t.start()
        return True

    def swap_if_ready(self) -> bool:
        """Atomically publish a completed shadow generation.  Called between
        train steps — never concurrently with a reader holding a snapshot."""
        with self._lock:
            # error take-and-clear inside the lock: the old lock-free read
            # could race the builder's error publish and drop it
            err = self._refresh_err
            self._refresh_err = None
            if err is None:
                if self._shadow is None:
                    return False
                self._live, self._shadow = self._shadow, None
                self.swaps += 1
                return True
        raise err

    def wait_refresh(self, timeout: Optional[float] = None) -> bool:
        """Block until an in-flight refresh finishes, then swap it in."""
        with self._lock:      # pairs with begin_refresh's publish-and-start
            t = self._thread
        if t is not None:
            t.join(timeout)
        return self.swap_if_ready()

    # ------------------------------------------------------------------
    # pod-scale shape helpers (used by launch/dryrun_gnn.py)
    # ------------------------------------------------------------------
    @staticmethod
    def padded_rows(num_nodes: int, fraction: float, multiple: int = 1) -> int:
        """Device-table row count, padded so `multiple` shards divide evenly
        (shape-only callers like launch/dryrun_gnn.py; delegates to
        ``CacheConfig.size`` so the padding rule has one home)."""
        return CacheConfig(fraction=fraction, shards=multiple).size(num_nodes)

"""Multi-tier feature store: device cache → pinned staging → host features.

Public surface:

* :class:`FeatureStore` — the facade (tier reads, refresh lifecycle,
  double-buffered async refresh with atomic generation swap).
* :class:`CachePolicy` + ``POLICIES`` / ``register_policy`` / ``make_policy``
  — the pluggable cache-admission policy registry.
* :class:`TrafficMeter` / :class:`TierStats` — per-tier traffic accounting.
* ``CacheConfig`` / ``CacheState`` / ``sample_cache`` / ``cache_probs`` —
  the §3.2 cache-sampling machinery (absorbed from ``repro.core.cache``).
* :class:`PlacementMap` + ``solve_placement`` / ``identity_placement`` /
  ``home_shard`` — locality-aware slot -> (shard, local row) placement from
  observed per-DP-group traffic (``CacheConfig(placement="locality")``).
"""
from repro.featurestore.meter import TierStats, TrafficMeter
from repro.featurestore.placement import (PlacementMap, RoutingTable,
                                          home_shard, identity_placement,
                                          routing_table_from_state,
                                          solve_placement)
from repro.featurestore.policies import (CachePolicy, POLICIES, make_policy,
                                         register_policy, degree_cache_probs,
                                         random_walk_cache_probs,
                                         reverse_pagerank_cache_probs,
                                         uniform_cache_probs)
from repro.featurestore.store import (CacheConfig, CacheState, FeatureStore,
                                      Generation, cache_probs, sample_cache)

__all__ = [
    "FeatureStore", "Generation", "CacheConfig", "CacheState",
    "cache_probs", "sample_cache",
    "CachePolicy", "POLICIES", "make_policy", "register_policy",
    "degree_cache_probs", "random_walk_cache_probs",
    "reverse_pagerank_cache_probs", "uniform_cache_probs",
    "TrafficMeter", "TierStats",
    "PlacementMap", "home_shard", "identity_placement", "solve_placement",
    "RoutingTable", "routing_table_from_state",
]

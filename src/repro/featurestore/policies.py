"""Pluggable cache-admission policies for the feature store.

Every policy maps a graph (plus optional training set and observed access
feedback) to a score per node; the cache generation is drawn from the
normalized scores by Gumbel top-k (see ``store.sample_cache``).

Shipped policies:

* ``degree``           — eq. (6): p_i ∝ deg(i).
* ``random_walk``      — eqs. (7)–(9): L-step fanout-weighted walk mass from
  the training set; used when V_S is a small fraction of V.
* ``uniform``          — baseline.
* ``reverse_pagerank`` — weighted reverse PageRank over sampling-reachability
  (*Graph Neural Network Training with Data Tiering*, arXiv:2111.05894):
  importance flows backward along edges with the per-source visit probability
  min(fanout/deg, 1), restarted at the training set.
* ``adaptive``         — EMA of observed request frequencies (the full
  requested-id traffic — hits AND misses — fed back through ``observe``);
  converges onto the realized working set, degree prior for cold start.
  Feeding only misses starves the EMA of nodes once they become hits, so
  they decay, get evicted, miss again — oscillating churn.

Registering a new policy::

    @register_policy
    class MyPolicy(CachePolicy):
        name = "mine"
        def scores(self, graph, train_idx=None): ...
"""
from __future__ import annotations

import inspect
import threading
from typing import Dict, Optional, Sequence, Type

import numpy as np

from repro.analysis import guarded_by


# ---------------------------------------------------------------------------
# probability constructions (pure functions, formerly repro.core.cache)
# ---------------------------------------------------------------------------

def degree_cache_probs(g) -> np.ndarray:
    """eq. (6): p_i = deg(i) / Σ deg(k)."""
    deg = g.degrees.astype(np.float64)
    s = deg.sum()
    if s == 0:
        return np.full(g.num_nodes, 1.0 / g.num_nodes)
    return deg / s


def random_walk_cache_probs(g, train_idx: np.ndarray,
                            fanouts: Sequence[int]) -> np.ndarray:
    """eqs. (7)–(9): L-step fanout-weighted walk mass from the training set.

    P^ℓ = (D·A + I) P^{ℓ-1} with D = diag(fanout_ℓ / deg).  The product
    fanout/deg is exactly the probability that a specific neighbor is drawn by
    node-wise sampling with that layer's fanout, so P^L is the expected
    visitation mass of node-wise sampling rooted at the training set.
    """
    n = g.num_nodes
    p = np.zeros(n, dtype=np.float64)
    p[train_idx] = 1.0 / max(len(train_idx), 1)
    deg = np.maximum(g.degrees, 1).astype(np.float64)
    src = np.repeat(np.arange(n, dtype=np.int64), g.degrees)  # edge sources
    dst = g.indices.astype(np.int64)
    for fanout in fanouts:
        scale = np.minimum(fanout / deg, 1.0)                 # row weight of D·A
        contrib = p[src] * scale[src]
        nxt = p.copy()                                        # the +I term
        np.add.at(nxt, dst, contrib)
        p = nxt
        s = p.sum()
        if s > 0:
            p /= s
    return p


def reverse_pagerank_cache_probs(g, train_idx: Optional[np.ndarray],
                                 alpha: float = 0.85, iters: int = 20,
                                 fanout: int = 10) -> np.ndarray:
    """Weighted reverse PageRank over sampling-reachability (arXiv:2111.05894).

    Node u accumulates importance from every v with u ∈ N(v), weighted by the
    probability min(fanout/deg(v), 1) that node-wise sampling at v visits a
    specific neighbor — i.e. PageRank run on the *reverse* sampling graph —
    with restart mass on the training set (uniform on V if none given).
    """
    n = g.num_nodes
    r = np.zeros(n, dtype=np.float64)
    if train_idx is not None and len(train_idx):
        r[train_idx] = 1.0 / len(train_idx)
    else:
        r[:] = 1.0 / n
    deg = np.maximum(g.degrees, 1).astype(np.float64)
    scale = np.minimum(fanout / deg, 1.0)
    src = np.repeat(np.arange(n, dtype=np.int64), g.degrees)
    dst = g.indices.astype(np.int64)
    p = r.copy()
    for _ in range(iters):
        flow = np.zeros(n, dtype=np.float64)
        np.add.at(flow, dst, p[src] * scale[src])   # reverse edge u<-v flow
        p = (1.0 - alpha) * r + alpha * flow
        s = p.sum()
        if s > 0:
            p /= s
    return p


def uniform_cache_probs(g) -> np.ndarray:
    return np.full(g.num_nodes, 1.0 / g.num_nodes)


# ---------------------------------------------------------------------------
# policy objects + registry
# ---------------------------------------------------------------------------

class CachePolicy:
    """Scores nodes for cache admission; stateful policies learn from traffic."""

    name: str = "base"
    stateful: bool = False      # True -> scores change between refreshes

    def bind(self, graph, train_idx: Optional[np.ndarray] = None) -> None:
        """Attach to a graph (allocate per-node state).  Idempotent."""

    def observe(self, ids: np.ndarray) -> None:
        """Feed back the node ids requested from the cache this batch — the
        full traffic, hits and misses alike (no-op unless stateful)."""

    def scores(self, graph, train_idx: Optional[np.ndarray] = None) -> np.ndarray:
        raise NotImplementedError

    def probs(self, graph, train_idx: Optional[np.ndarray] = None) -> np.ndarray:
        s = np.asarray(self.scores(graph, train_idx), dtype=np.float64)
        s = np.maximum(s, 0.0)
        tot = s.sum()
        if tot <= 0:
            return np.full(graph.num_nodes, 1.0 / graph.num_nodes)
        return s / tot


POLICIES: Dict[str, Type[CachePolicy]] = {}


def register_policy(cls: Type[CachePolicy]) -> Type[CachePolicy]:
    POLICIES[cls.name] = cls
    return cls


def make_policy(name: str, **kwargs) -> CachePolicy:
    """Instantiate a registered policy, passing only the kwargs it accepts."""
    try:
        cls = POLICIES[name]
    except KeyError:
        raise ValueError(f"unknown cache policy: {name!r} "
                         f"(registered: {sorted(POLICIES)})") from None
    sig = inspect.signature(cls.__init__)
    kw = {k: v for k, v in kwargs.items() if k in sig.parameters}
    return cls(**kw)


@register_policy
class DegreePolicy(CachePolicy):
    name = "degree"

    def scores(self, graph, train_idx=None) -> np.ndarray:
        return degree_cache_probs(graph)


@register_policy
class UniformPolicy(CachePolicy):
    name = "uniform"

    def scores(self, graph, train_idx=None) -> np.ndarray:
        return uniform_cache_probs(graph)


@register_policy
class RandomWalkPolicy(CachePolicy):
    name = "random_walk"

    def __init__(self, walk_fanouts: Sequence[int] = (15, 10, 5)):
        self.walk_fanouts = tuple(walk_fanouts)

    def scores(self, graph, train_idx=None) -> np.ndarray:
        assert train_idx is not None, "random_walk policy needs train_idx"
        return random_walk_cache_probs(graph, train_idx, self.walk_fanouts)


@register_policy
class ReversePageRankPolicy(CachePolicy):
    name = "reverse_pagerank"

    def __init__(self, alpha: float = 0.85, iters: int = 20, fanout: int = 10):
        self.alpha, self.iters, self.fanout = alpha, iters, fanout

    def scores(self, graph, train_idx=None) -> np.ndarray:
        return reverse_pagerank_cache_probs(graph, train_idx, alpha=self.alpha,
                                            iters=self.iters, fanout=self.fanout)


@register_policy
@guarded_by("_lock", "_ema", "_prior")
class AdaptivePolicy(CachePolicy):
    """EMA of observed request traffic, degree prior for cold start.

    ``observe`` is called with every node id requested from the device cache
    — hits as well as misses (the store feeds the full batch traffic).  The
    per-node EMA decays by ``decay`` at every refresh, so the scores track
    the recent working set.  Observing only misses would starve cached nodes
    of feedback: their EMA decays to the prior, they get evicted, miss, get
    readmitted — churn that the regression test in tests/test_featurestore.py
    pins down.  With no observations yet the policy degenerates to the degree
    policy (prior mass ``prior_weight``), so the first generation matches the
    paper's eq. (6) cache.
    """

    name = "adaptive"
    stateful = True

    def __init__(self, decay: float = 0.8, prior_weight: float = 1.0):
        self.decay = decay
        self.prior_weight = prior_weight
        self._ema: Optional[np.ndarray] = None
        self._prior: Optional[np.ndarray] = None
        # observe() runs on the sampling thread while scores() runs on the
        # async-refresh builder thread; numpy buffer ops release the GIL,
        # so guard the EMA read/decay/accumulate explicitly.
        self._lock = threading.Lock()

    def bind(self, graph, train_idx=None) -> None:
        with self._lock:
            if self._ema is None or len(self._ema) != graph.num_nodes:
                self._ema = np.zeros(graph.num_nodes, dtype=np.float64)
                self._prior = degree_cache_probs(graph)

    def observe(self, ids: np.ndarray) -> None:
        if len(ids) == 0:
            return
        with self._lock:
            # the not-yet-bound check belongs INSIDE the lock: bind() may be
            # concurrently installing the EMA buffer from the builder thread
            if self._ema is None:
                return
            np.add.at(self._ema, np.asarray(ids, dtype=np.int64), 1.0)

    def scores(self, graph, train_idx=None) -> np.ndarray:
        self.bind(graph, train_idx)
        with self._lock:
            s = self._ema + self.prior_weight * self._prior
            self._ema *= self.decay      # decay once per refresh
        return s

"""Traffic accounting for the multi-tier feature store.

The paper's central systems claim is that a small device-pinned cache removes
most of the host→device feature traffic (Fig. 1: 60–80% of step time is data
copy).  :class:`TrafficMeter` accounts every byte that crosses a tier
boundary so the benchmark harness can reproduce the paper's breakdown
(Fig. 2, Table 4) — now per tier:

* ``device``  — the device-resident cache table (tier 0)
* ``staging`` — the pinned-host staging buffer mirroring the device table
* ``host``    — the full host feature array (tier 2, the slow path)
"""
from __future__ import annotations

import dataclasses
from typing import Dict


@dataclasses.dataclass
class TierStats:
    """Hit/miss/byte counters for one storage tier."""
    name: str
    hits: int = 0
    misses: int = 0
    bytes_read: int = 0

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def as_dict(self) -> dict:
        return {"hits": self.hits, "misses": self.misses,
                "bytes_read": self.bytes_read,
                "hit_rate": round(self.hit_rate, 4)}


@dataclasses.dataclass
class TrafficMeter:
    """Aggregate host↔device + host-memory traffic counters (bytes / seconds)."""
    bytes_streamed: int = 0        # host -> device feature rows (PCIe analog)
    bytes_sliced: int = 0          # host-memory gather (CPU bandwidth, step 2)
    bytes_cache_fill: int = 0      # cache refresh host-side gather (|C| rows)
    bytes_cache_upload: int = 0    # cache refresh host->device transfer: sum of
                                   # bytes actually landed on each device — a
                                   # shard-aware upload pays table/n_shards per
                                   # device, a replicated one pays the full table
    uploads: int = 0               # device-table uploads (one per generation)
    t_sample: float = 0.0
    t_slice: float = 0.0
    t_copy: float = 0.0
    t_compute: float = 0.0
    t_refresh: float = 0.0         # background cache-generation build time
    steps: int = 0
    tiers: Dict[str, TierStats] = dataclasses.field(default_factory=dict)

    def tier(self, name: str) -> TierStats:
        """Per-tier counters, created on first touch."""
        ts = self.tiers.get(name)
        if ts is None:
            ts = self.tiers[name] = TierStats(name)
        return ts

    def add_batch(self, bytes_streamed: int):
        self.bytes_streamed += bytes_streamed
        self.bytes_sliced += bytes_streamed
        self.steps += 1

    def breakdown(self) -> dict:
        total = self.t_sample + self.t_slice + self.t_copy + self.t_compute
        out = {
            "sample_s": round(self.t_sample, 4),
            "slice_s": round(self.t_slice, 4),
            "copy_s": round(self.t_copy, 4),
            "compute_s": round(self.t_compute, 4),
            "total_s": round(total, 4),
            "refresh_s": round(self.t_refresh, 4),
            "bytes_streamed": self.bytes_streamed,
            "bytes_cache_fill": self.bytes_cache_fill,
            "bytes_cache_upload": self.bytes_cache_upload,
            "uploads": self.uploads,
            "steps": self.steps,
        }
        if self.tiers:
            out["tiers"] = {k: v.as_dict() for k, v in self.tiers.items()}
        return out

"""Traffic accounting for the multi-tier feature store.

The paper's central systems claim is that a small device-pinned cache removes
most of the host→device feature traffic (Fig. 1: 60–80% of step time is data
copy).  :class:`TrafficMeter` accounts every byte that crosses a tier
boundary so the benchmark harness can reproduce the paper's breakdown
(Fig. 2, Table 4) — now per tier:

* ``device``  — the device-resident cache table (tier 0)
* ``staging`` — the pinned-host staging buffer mirroring the device table
* ``host``    — the full host feature array (tier 2, the slow path)

Locality accounting (PR 3): the meter additionally grows **per-DP-group
request histograms** (``observe_group`` — node-id request counts per group,
the input to ``featurestore.placement.solve_placement``) and counts each
cache hit as *local* or *remote* depending on whether the row's shard is the
requesting group's home shard (``lanes_local`` / ``lanes_remote`` /
``local_hit_fraction``) — the cross-shard lookup traffic the locality-aware
placement minimizes.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np


@dataclasses.dataclass
class TierStats:
    """Hit/miss/byte counters for one storage tier."""
    name: str
    hits: int = 0
    misses: int = 0
    bytes_read: int = 0

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def as_dict(self) -> dict:
        return {"hits": self.hits, "misses": self.misses,
                "bytes_read": self.bytes_read,
                "hit_rate": round(self.hit_rate, 4)}


@dataclasses.dataclass
class TrafficMeter:
    """Aggregate host↔device + host-memory traffic counters (bytes / seconds)."""
    bytes_streamed: int = 0        # host -> device feature rows (PCIe analog)
    bytes_sliced: int = 0          # host-memory gather (CPU bandwidth, step 2)
    bytes_cache_fill: int = 0      # cache refresh host-side gather (|C| rows)
    bytes_cache_upload: int = 0    # cache refresh host->device transfer: sum of
                                   # bytes actually landed on each device — a
                                   # shard-aware upload pays table/n_shards per
                                   # device, a replicated one pays the full table
    bytes_adj_upload: int = 0      # per-generation cache-adjacency CSR
                                   # host->device transfer (backend="device"
                                   # sampling) — kept separate from
                                   # bytes_cache_upload so the 1/n sharded-
                                   # upload acceptance ratio stays a pure
                                   # feature-table number
    bytes_delta_upload: int = 0    # streaming-ingest payload absorbed at
                                   # generation merges (edge-op log + new-
                                   # node feature/label rows) — separate
                                   # from bytes_cache_upload/bytes_adj_upload
                                   # for the same reason: the 1/n upload-
                                   # ratio assert must never see ingest bytes
    bytes_rpc_tx: int = 0          # host->host RPC frames shipped (wire
                                   # header + meta + payload) — the fabric's
                                   # cross-host serving transport
    bytes_rpc_rx: int = 0          # host->host RPC frames received
    uploads: int = 0               # device-table uploads (one per generation)
    lanes_local: int = 0           # cache hits served by the requesting
                                   # group's home shard (no cache-axis hop)
    lanes_remote: int = 0          # cache hits resolved on another shard
                                   # (cross-shard traffic the placement
                                   # solver exists to remove)
    bytes_cross_shard: int = 0     # remote-hit rows x row bytes
    t_sample: float = 0.0
    t_slice: float = 0.0
    t_copy: float = 0.0
    t_compute: float = 0.0
    t_refresh: float = 0.0         # background cache-generation build time
    t_prefetch_wait: float = 0.0   # consumer time blocked on the prefetch
                                   # queue (sampler-stall; ROADMAP item 2's
                                   # success metric — device-backend sampling
                                   # exists to drive this to ~0)
    steps: int = 0
    tiers: Dict[str, TierStats] = dataclasses.field(default_factory=dict)
    group_hist: Dict[int, np.ndarray] = dataclasses.field(default_factory=dict)
                                   # DP group -> per-node request counts

    def tier(self, name: str) -> TierStats:
        """Per-tier counters, created on first touch."""
        ts = self.tiers.get(name)
        if ts is None:
            ts = self.tiers[name] = TierStats(name)
        return ts

    @property
    def local_hit_fraction(self) -> float:
        """Fraction of cache hits the requesting group's home shard served."""
        total = self.lanes_local + self.lanes_remote
        return self.lanes_local / total if total else 0.0

    def observe_group(self, group: int, ids: np.ndarray,
                      num_nodes: int) -> None:
        """Accumulate one DP group's requested node ids (hits AND misses —
        the placement solver wants the demand, not the current hit set)."""
        if len(ids) == 0:
            return
        hist = self.group_hist.get(group)
        if hist is None or len(hist) > num_nodes:
            hist = self.group_hist[group] = np.zeros(num_nodes, np.float64)
        elif len(hist) < num_nodes:
            # id space grew (streaming merge): PAD, never reset — the
            # placement solver's demand signal must survive the merge or
            # every generation after an ingest would cold-start contiguous
            grown = np.zeros(num_nodes, np.float64)
            grown[:len(hist)] = hist
            hist = self.group_hist[group] = grown
        np.add.at(hist, np.asarray(ids, dtype=np.int64), 1.0)

    def group_slot_traffic(self, node_ids: np.ndarray,
                           table_rows: int) -> Optional[np.ndarray]:
        """Histograms restricted to one generation's membership, padded to
        the device-table rows — the [n_groups, table_rows] input of
        ``placement.solve_placement`` (None until any traffic is seen).
        Padding slots (``len(node_ids) <= slot < table_rows``) carry zero
        counts, so the solver parks them on whatever capacity is left."""
        if not self.group_hist:
            return None
        groups = sorted(self.group_hist)
        node_ids = np.asarray(node_ids, dtype=np.int64)
        out = np.zeros((len(groups), table_rows), np.float64)
        for gi, g in enumerate(groups):
            hist = self.group_hist[g]
            # ids beyond the histogram are nodes merged in after the last
            # observation — zero demand until traffic touches them
            known = node_ids < len(hist)
            out[gi, :len(node_ids)][known] = hist[node_ids[known]]
        return out

    def group_ids(self) -> list:
        return sorted(self.group_hist)

    def add_batch(self, bytes_streamed: int):
        self.bytes_streamed += bytes_streamed
        self.bytes_sliced += bytes_streamed
        self.steps += 1

    def breakdown(self) -> dict:
        total = self.t_sample + self.t_slice + self.t_copy + self.t_compute
        out = {
            "sample_s": round(self.t_sample, 4),
            "slice_s": round(self.t_slice, 4),
            "copy_s": round(self.t_copy, 4),
            "compute_s": round(self.t_compute, 4),
            "total_s": round(total, 4),
            "refresh_s": round(self.t_refresh, 4),
            "prefetch_wait_s": round(self.t_prefetch_wait, 4),
            "bytes_streamed": self.bytes_streamed,
            "bytes_cache_fill": self.bytes_cache_fill,
            "bytes_cache_upload": self.bytes_cache_upload,
            "bytes_adj_upload": self.bytes_adj_upload,
            "bytes_delta_upload": self.bytes_delta_upload,
            "bytes_rpc_tx": self.bytes_rpc_tx,
            "bytes_rpc_rx": self.bytes_rpc_rx,
            "uploads": self.uploads,
            "steps": self.steps,
            "lanes_local": self.lanes_local,
            "lanes_remote": self.lanes_remote,
            "local_hit_fraction": round(self.local_hit_fraction, 4),
            "bytes_cross_shard": self.bytes_cross_shard,
        }
        if self.tiers:
            out["tiers"] = {k: v.as_dict() for k, v in self.tiers.items()}
        return out

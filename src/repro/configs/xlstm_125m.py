"""xlstm-125m — xLSTM language model (mLSTM + sLSTM blocks).

[arXiv:2405.04517; unverified]  12L d_model=768 4H d_ff=0 vocab=50304.

The xLSTM paper's 125M models use an mLSTM:sLSTM block ratio of 7:1
("xLSTM[7:1]"); with 12 blocks we place sLSTM at indices (3, 9) and mLSTM
elsewhere (source tier is 'unverified' — the ratio, dims and head count are
the published numbers, the exact placement is our choice, recorded here).
d_ff=0: xLSTM blocks have no separate FFN — the mLSTM up-projection
(proj_factor 2.0) plays that role.

O(1) recurrent decode state (matrix memory C, normalizer n, stabilizer m)
=> this arch RUNS the long_500k decode shape.
"""
from repro.configs.base import ArchConfig, XLSTMCfg


def config() -> ArchConfig:
    return ArchConfig(
        name="xlstm-125m",
        family="ssm",
        num_layers=12,
        d_model=768,
        num_heads=4,
        num_kv_heads=4,
        d_ff=0,
        vocab_size=50304,
        xlstm=XLSTMCfg(slstm_at=(3, 9), num_heads=4, proj_factor=2.0,
                       qk_factor=0.5),
        tie_embeddings=True,
        supports_long_context=True,
        long_context_note="O(1) recurrent state: long_500k runs",
        source="arXiv:2405.04517; unverified",
    )

"""Unified architecture config + assigned input shapes.

One frozen dataclass covers all 10 assigned LM-family architectures; family-
specific sub-configs (MoE / MLA / SSM / xLSTM) are optional fields.  Every
arch file instantiates the exact published numbers; ``reduced()`` produces
the same *family* at smoke-test scale (small dims, same block pattern).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoECfg:
    num_experts: int
    top_k: int
    d_expert: int                  # routed expert hidden dim
    num_shared: int = 0            # always-on shared experts (deepseek: 2)
    dense_residual: bool = False   # dense FFN in parallel with MoE (arctic)
    first_dense_layers: int = 0    # leading dense layers (deepseek: 1)
    capacity_factor: float = 1.25
    router_dtype: str = "float32"


@dataclasses.dataclass(frozen=True)
class MLACfg:
    q_lora: int = 1536
    kv_lora: int = 512
    qk_nope: int = 128
    qk_rope: int = 64
    v_head: int = 128


@dataclasses.dataclass(frozen=True)
class SSMCfg:
    d_state: int = 64
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    chunk: int = 64
    n_groups: int = 1


@dataclasses.dataclass(frozen=True)
class XLSTMCfg:
    slstm_at: Tuple[int, ...] = ()   # layer indices running sLSTM blocks
    num_heads: int = 4
    proj_factor: float = 2.0         # mLSTM up-projection
    qk_factor: float = 0.5           # qk dim = qk_factor * d_inner
    conv_kernel: int = 4
    chunk: int = 0                   # 0 = parallel [S,S] form (paper);
                                     # >0 = chunkwise kernel form (§Perf)


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                       # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None    # default d_model // num_heads
    attn_bias: bool = False           # qwen2 QKV bias
    sliding_window: Optional[int] = None
    rope_theta: float = 1e4
    ffn_act: str = "silu"             # gate activation (silu=SwiGLU, gelu=GeGLU)
    gated_ffn: bool = True
    norm_type: str = "rmsnorm"
    tie_embeddings: bool = False
    scale_embed: bool = False         # gemma: h0 = embed * sqrt(d_model)
    moe: Optional[MoECfg] = None
    mla: Optional[MLACfg] = None
    ssm: Optional[SSMCfg] = None
    xlstm: Optional[XLSTMCfg] = None
    shared_attn_every: int = 0        # zamba2: shared attn block cadence
    encoder_layers: int = 0           # >0 -> encoder-decoder
    frontend: Optional[str] = None    # audio | vision (STUB embeddings)
    frontend_tokens: int = 256        # vision tokens prepended (vlm)
    supports_long_context: bool = False
    long_context_note: str = ""
    dtype: str = "bfloat16"
    remat: bool = True
    attn_impl: str = "reference"      # reference | pallas
    fsdp: bool = False                # ZeRO-style param/opt sharding over DP
    grad_accum: int = 1               # microbatch accumulation in train_step
    chunked_ce: int = 0               # 0 = plain CE; >0 = fused block-wise
                                      # unembed+CE, never materializes
                                      # [B,S,V] logits (§Perf)
    bf16_grad_stream: bool = False    # grad_cast at block boundaries: pin
                                      # backward residual cotangents to the
                                      # forward dtype (§Perf deepseek it. 2)
    pure_dp: bool = False             # batch over ALL mesh axes + ZeRO-3
                                      # param sharding, no TP — the right
                                      # regime for <=7B dense archs (§Perf);
                                      # not valid for MoE (experts need the
                                      # model axis)
    source: str = ""                  # provenance tag

    @property
    def head_dim_eff(self) -> int:
        return self.head_dim or (self.d_model // self.num_heads)

    def reduced(self) -> "ArchConfig":
        """Smoke-test scale: tiny dims, same family/block pattern."""
        changes: dict = dict(
            num_layers=min(self.num_layers, 4),
            d_model=64,
            num_heads=4,
            num_kv_heads=min(self.num_kv_heads, 2) if self.num_kv_heads < self.num_heads else 4,
            head_dim=16,
            d_ff=128 if self.d_ff else 0,
            vocab_size=512,
            sliding_window=min(self.sliding_window, 16) if self.sliding_window else None,
            frontend_tokens=8 if self.frontend else self.frontend_tokens,
            encoder_layers=min(self.encoder_layers, 2),
            remat=False,
            dtype="float32",
            fsdp=False,
            grad_accum=1,
        )
        if self.moe:
            changes["moe"] = dataclasses.replace(
                self.moe, num_experts=4, top_k=min(self.moe.top_k, 2),
                d_expert=32,
                num_shared=min(self.moe.num_shared, 1),
                first_dense_layers=min(self.moe.first_dense_layers, 1))
        if self.mla:
            changes["mla"] = MLACfg(q_lora=32, kv_lora=16, qk_nope=16,
                                    qk_rope=8, v_head=16)
        if self.ssm:
            changes["ssm"] = dataclasses.replace(
                self.ssm, d_state=16, head_dim=16, chunk=16)
        if self.xlstm:
            changes["xlstm"] = dataclasses.replace(
                self.xlstm, slstm_at=tuple(i for i in self.xlstm.slstm_at
                                           if i < changes["num_layers"]) or (1,),
                num_heads=2)
        if self.shared_attn_every:
            changes["shared_attn_every"] = 2
        return dataclasses.replace(self, **changes)


# ---------------------------------------------------------------------------
# Assigned input shapes (identical set for every LM arch)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str             # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k":    ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k":  ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k":   ShapeSpec("long_500k", 524_288, 1, "decode"),
}


def shape_applicable(cfg: ArchConfig, shape: ShapeSpec) -> tuple[bool, str]:
    """long_500k needs sub-quadratic attention (DESIGN.md §5)."""
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return False, (cfg.long_context_note or
                       "pure full-attention arch: 500k decode skipped")
    return True, ""


def smoke_shape(kind: str) -> ShapeSpec:
    """Tiny shape for CPU smoke tests."""
    if kind == "train":
        return ShapeSpec("smoke_train", 32, 2, "train")
    if kind == "prefill":
        return ShapeSpec("smoke_prefill", 32, 1, "prefill")
    return ShapeSpec("smoke_decode", 32, 2, "decode")

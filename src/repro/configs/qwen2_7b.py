"""qwen2-7b — dense decoder, GQA with QKV bias.

[arXiv:2407.10671; hf Qwen/Qwen2-7B]  28L d_model=3584 28H (GQA kv=4)
d_ff=18944 vocab=152064, QKV bias (the qwen signature), rope_theta=1e6,
SwiGLU + RMSNorm.
"""
from repro.configs.base import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="qwen2-7b",
        family="dense",
        num_layers=28,
        d_model=3584,
        num_heads=28,
        num_kv_heads=4,
        d_ff=18944,
        vocab_size=152064,
        attn_bias=True,
        rope_theta=1e6,
        supports_long_context=False,
        long_context_note="pure full-attention arch: 500k decode skipped",
        source="arXiv:2407.10671; hf",
    )

"""arctic-480b — dense-MoE hybrid: 128-expert top-2 MoE + dense residual.

[hf Snowflake/snowflake-arctic-base]  35L d_model=7168 56H (GQA kv=8)
d_ff=4864 vocab=32000, MoE 128 experts top-2, with a dense transformer
residual in parallel with the routed experts (Arctic's "Dense-MoE hybrid").

Largest memory cell of the assignment (~482B params): requires ZeRO/FSDP
param+optimizer sharding over the DP axes on top of EP over 'model', plus
bf16 optimizer moments and grad accumulation (EXPERIMENTS.md §Dry-run).
"""
from repro.configs.base import ArchConfig, MoECfg


def config() -> ArchConfig:
    return ArchConfig(
        name="arctic-480b",
        family="moe",
        num_layers=35,
        d_model=7168,
        num_heads=56,
        num_kv_heads=8,
        d_ff=4864,                # dense residual FFN dim
        vocab_size=32000,
        moe=MoECfg(num_experts=128, top_k=2, d_expert=4864,
                   dense_residual=True),
        supports_long_context=False,
        long_context_note="pure full-attention arch: 500k decode skipped",
        fsdp=True,
        source="hf:Snowflake/snowflake-arctic-base",
    )

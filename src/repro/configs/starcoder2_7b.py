"""starcoder2-7b — dense code LM, GQA + RoPE, non-gated GELU FFN.

[arXiv:2402.19173; hf bigcode/starcoder2-7b]  32L d_model=4608 36H
(GQA kv=4) d_ff=18432 (= 4x) vocab=49152.  starcoder2 uses a plain GELU MLP
(not gated), LayerNorm-family norms, learned biases on projections, and
rope_theta=1e5 for the 16k context.
"""
from repro.configs.base import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="starcoder2-7b",
        family="dense",
        num_layers=32,
        d_model=4608,
        num_heads=36,
        num_kv_heads=4,
        d_ff=18432,
        vocab_size=49152,
        attn_bias=True,
        ffn_act="gelu_tanh",
        gated_ffn=False,
        rope_theta=1e5,
        supports_long_context=False,
        long_context_note="pure full-attention arch: 500k decode skipped",
        source="arXiv:2402.19173; hf",
    )

"""Architecture configs: the 10 assigned archs + the paper's own GraphSAGE.

``get_config(name)`` returns the exact published configuration;
``get_config(name).reduced()`` returns the CPU-smoke-test scale-down of the
same family (same block pattern, tiny dims).
"""
from repro.configs.base import (ArchConfig, MoECfg, MLACfg, SSMCfg, XLSTMCfg,
                                ShapeSpec, SHAPES, shape_applicable)

_ARCH_MODULES = [
    "seamless_m4t_medium", "internvl2_1b", "deepseek_v2_236b", "arctic_480b",
    "xlstm_125m", "gemma_2b", "h2o_danube_3_4b", "starcoder2_7b", "qwen2_7b",
    "zamba2_2_7b",
]


def list_archs() -> list:
    return [m.replace("_", "-").replace("zamba2-2-7b", "zamba2-2.7b")
            .replace("h2o-danube-3-4b", "h2o-danube-3-4b") for m in _ARCH_MODULES]


def get_config(name: str) -> ArchConfig:
    mod_name = name.replace("-", "_").replace(".", "_")
    import importlib
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.config()


__all__ = ["ArchConfig", "MoECfg", "MLACfg", "SSMCfg", "XLSTMCfg",
           "ShapeSpec", "SHAPES", "shape_applicable", "get_config", "list_archs"]

"""internvl2-1b — VLM: InternViT frontend (STUB) + Qwen2-0.5B LM backbone.

[arXiv:2404.16821; hf OpenGVLab/InternVL2-1B]  24L d_model=896 14H
(GQA kv=2) d_ff=4864 vocab=151655.

Backbone only: the InternViT-300M patch embedder is a STUB — ``input_specs()``
provides precomputed patch embeddings [B, 256, d_model] prepended to the text
sequence.  The LM is the Qwen2 family: QKV bias, GQA kv=2, SwiGLU,
rope_theta=1e6 (qwen2-0.5b HF config).
"""
from repro.configs.base import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="internvl2-1b",
        family="vlm",
        num_layers=24,
        d_model=896,
        num_heads=14,
        num_kv_heads=2,
        d_ff=4864,
        vocab_size=151655,
        attn_bias=True,
        rope_theta=1e6,
        tie_embeddings=True,      # qwen2-0.5b ties embeddings
        frontend="vision",
        frontend_tokens=256,
        supports_long_context=False,
        long_context_note="pure full-attention arch: 500k decode skipped",
        source="arXiv:2404.16821; hf",
    )

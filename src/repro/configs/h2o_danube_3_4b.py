"""h2o-danube-3-4b — dense decoder, llama+mistral mix with sliding-window attn.

[arXiv:2401.16818; unverified]  24L d_model=3840 32H (GQA kv=8) d_ff=10240
vocab=32000.  The danube recipe mixes llama (SwiGLU, RMSNorm, RoPE) with
mistral components — per the assignment the sliding-window attention is kept
(window 4096, the mistral default; source tier 'unverified', choice recorded).

head_dim = 3840/32 = 120 — NOT a multiple of 128; the roofline analysis flags
the resulting MXU padding (EXPERIMENTS.md §Roofline).

SWA => decode keeps a ring-buffer KV of window size, so memory is O(window)
not O(seq): this arch RUNS the long_500k decode shape.
"""
from repro.configs.base import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="h2o-danube-3-4b",
        family="dense",
        num_layers=24,
        d_model=3840,
        num_heads=32,
        num_kv_heads=8,
        d_ff=10240,
        vocab_size=32000,
        sliding_window=4096,
        rope_theta=1e5,
        supports_long_context=True,
        long_context_note="SWA ring-buffer KV (window 4096): long_500k runs",
        source="arXiv:2401.16818; unverified",
    )

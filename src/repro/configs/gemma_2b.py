"""gemma-2b — dense decoder, MQA, GeGLU, head_dim=256.

[arXiv:2403.08295; hf google/gemma-2b]  18L d_model=2048 8H (MQA kv=1)
d_ff=16384 vocab=256000, GeGLU activation, head_dim=256 (> d_model/H),
tied embeddings, embeddings scaled by sqrt(d_model).
"""
from repro.configs.base import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="gemma-2b",
        family="dense",
        num_layers=18,
        d_model=2048,
        num_heads=8,
        num_kv_heads=1,
        head_dim=256,
        d_ff=16384,
        vocab_size=256000,
        ffn_act="gelu_tanh",      # GeGLU
        gated_ffn=True,
        tie_embeddings=True,
        scale_embed=True,
        supports_long_context=False,
        long_context_note="pure full-attention arch: 500k decode skipped",
        source="arXiv:2403.08295; hf",
    )

"""deepseek-v2-236b — MoE with Multi-head Latent Attention (MLA).

[arXiv:2405.04434; hf deepseek-ai/DeepSeek-V2]  60L d_model=5120 128H
(MLA: per-head KV materialized from a 512-dim latent) routed-expert
d_ff=1536, vocab=102400, MoE 160 routed experts top-6 + 2 shared experts,
first layer dense (HF first_k_dense_replace=1, dense intermediate 12288).

MLA dims (HF config): q_lora_rank=1536, kv_lora_rank=512, qk_nope_head_dim
=128, qk_rope_head_dim=64, v_head_dim=128.  The compressed KV cache
(512+64 dims/token/layer regardless of the 128 heads) is why we also run the
long_500k decode shape for this arch — flagged as a documented extra in
DESIGN.md §5: attention is mathematically full, but decode is O(seq) with a
sequence-sharded latent cache and the memory actually fits.

ZeRO/FSDP sharding + grad accumulation are on: 236B params do not fit a v5e
pod otherwise (EXPERIMENTS.md §Dry-run memory table).
"""
from repro.configs.base import ArchConfig, MLACfg, MoECfg


def config() -> ArchConfig:
    return ArchConfig(
        name="deepseek-v2-236b",
        family="moe",
        num_layers=60,
        d_model=5120,
        num_heads=128,
        num_kv_heads=128,
        d_ff=12288,               # leading dense layer (HF intermediate_size)
        vocab_size=102400,
        moe=MoECfg(num_experts=160, top_k=6, d_expert=1536, num_shared=2,
                   first_dense_layers=1),
        mla=MLACfg(q_lora=1536, kv_lora=512, qk_nope=128, qk_rope=64,
                   v_head=128),
        supports_long_context=True,
        long_context_note=("MLA compressed KV (576 dims/token/layer) makes "
                           "500k decode memory-feasible; run as documented "
                           "extra"),
        fsdp=True,
        source="arXiv:2405.04434; hf",
    )

"""seamless-m4t-medium — encoder-decoder multimodal (audio) backbone.

[arXiv:2308.11596; hf facebook/seamless-m4t-medium]  12L d_model=1024 16H
(GQA kv=16 = full MHA) d_ff=4096 vocab=256206.

Backbone only: 12 encoder + 12 decoder layers; the speech frontend
(wav2vec-BERT conformer) is a STUB — ``input_specs()`` provides precomputed
frame embeddings [B, S_enc, d_model] (DESIGN.md §5).  Encoder self-attention
is bidirectional; decoder is causal self-attn + cross-attn over the encoder
output.  The real model uses sinusoidal positions + LayerNorm; we keep the
repo-uniform RoPE/RMSNorm blocks (backbone dims are what the dry-run /
roofline exercise — noted as an adaptation).
"""
from repro.configs.base import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="seamless-m4t-medium",
        family="audio",
        num_layers=12,            # decoder layers
        encoder_layers=12,
        d_model=1024,
        num_heads=16,
        num_kv_heads=16,
        d_ff=4096,
        vocab_size=256206,
        ffn_act="relu",           # seamless uses ReLU FFNs
        gated_ffn=False,
        frontend="audio",
        supports_long_context=False,
        long_context_note="full-attention enc-dec: 500k decode skipped",
        source="arXiv:2308.11596; hf",
    )

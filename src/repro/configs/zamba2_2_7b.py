"""zamba2-2.7b — hybrid: Mamba2 backbone + shared attention blocks.

[arXiv:2411.15242; hf Zyphra/Zamba2-2.7B]  54L d_model=2560 32H (kv=32, full
MHA in the shared block) d_ff=10240 vocab=32000, ssm_state=64.

Zamba2's signature: 54 Mamba2 layers with a SINGLE shared transformer block
(full self-attention + FFN, one parameter set) invoked every 6 layers — 9
invocations reusing the same weights, each with its own KV cache.  (The HF
model alternates two shared blocks and adds per-invocation LoRA deltas; we
model the single shared block — the memory/compute shape is identical, noted
as an adaptation in DESIGN.md.)

Mamba2 dims: d_state=64, head_dim=64, expand=2 (d_inner=5120, 80 heads),
n_groups=1.  Hybrid recurrent+windowed state => RUNS long_500k (the 9 shared
KV caches are sequence-sharded; decode attention is O(seq) matvec).
"""
from repro.configs.base import ArchConfig, SSMCfg


def config() -> ArchConfig:
    return ArchConfig(
        name="zamba2-2.7b",
        family="hybrid",
        num_layers=54,
        d_model=2560,
        num_heads=32,
        num_kv_heads=32,
        d_ff=10240,               # shared block FFN
        vocab_size=32000,
        ssm=SSMCfg(d_state=64, d_conv=4, expand=2, head_dim=64, chunk=128,
                   n_groups=1),
        shared_attn_every=6,
        supports_long_context=True,
        long_context_note=("Mamba2 O(1) state + 9 shared-attn KV caches "
                           "(seq-sharded): long_500k runs"),
        source="arXiv:2411.15242; hf",
    )

"""Production mesh construction.

A FUNCTION, not a module constant — importing this module must never touch
jax device state (the dry-run sets XLA_FLAGS before first jax init; smoke
tests must keep seeing 1 device).

Mesh layout (DESIGN.md §4):
  single pod:  (data=16, model=16)            = 256 chips (v5e pod)
  multi-pod:   (pod=2, data=16, model=16)     = 512 chips

Axis roles: DP over ('pod', 'data'); TP / EP / cache-sharding over 'model';
SP (sequence sharding for long-context decode) borrows 'data' when the batch
cannot occupy it.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    ndev = 1
    for s in shape:
        ndev *= s
    devices = jax.devices()[:ndev]      # dry-run exposes 512 host devices;
    assert len(devices) == ndev, (      # single-pod uses the first 256
        f"need {ndev} devices, have {len(jax.devices())} — the dry-run must "
        f"set XLA_FLAGS=--xla_force_host_platform_device_count=512 before "
        f"any jax import")
    import numpy as _np
    return jax.sharding.Mesh(_np.asarray(devices).reshape(shape), axes)


def make_host_mesh(data: int = 1, model: int = 1) -> jax.sharding.Mesh:
    """Small mesh over however many devices this host exposes (tests)."""
    n = len(jax.devices())
    assert data * model <= n, (data, model, n)
    return jax.make_mesh((data, model), ("data", "model"))


def cache_shard_axis(mesh: jax.sharding.Mesh) -> str:
    """Mesh axis carrying the feature-store cache shards.

    The cache table rides the 'model' axis (TP / EP / cache-sharding share
    it, see the layout note above): DP groups each consume their own
    minibatch, so the row shards must live across an axis every DP group
    spans.  Falls back to the first axis on meshes without 'model'
    (1-D benchmark meshes)."""
    return "model" if "model" in mesh.axis_names else mesh.axis_names[0]

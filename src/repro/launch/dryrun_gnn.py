import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")
# ^ before any jax import (same contract as launch/dryrun.py)

"""Pod-scale dry-run of the PAPER's workload: GraphSAGE + GNS.

The 40 LM cells prove the framework; this proves the paper's own technique
at pod scale: the GNS train step — device cache table + padded minibatch
blocks + importance-weighted aggregation — lowered on the 16x16 (and
2x16x16) production mesh at ogbn-papers100M dimensions:

  * cache table [|C| = 1% of 111M = 1.11M rows, 128 feats] — row-sharded
    over the cache axis ('model'; the pod-scale cache the paper's single T4
    cannot hold), refreshed by SHARD-AWARE upload (each device receives only
    its own rows — table/n_shards per chip instead of the full table);
  * minibatch: batch 1000, fanouts (15,10,5) => padded input layer of
    176k nodes/batch, sharded over 'data' (one minibatch per data group is
    the paper's multi-GPU regime);
  * input path: the REAL one — ``SageConfig(input_impl="fused")``, the fused
    cache-lookup + layer-0 gather op shard_mapped over the cache axis
    (reference backend: interpret-mode Pallas at these grids cannot be
    lowered economically from a CPU host — same policy as kernels/ops.py);
  * train step = forward + backward + AdamW on the 3-layer GraphSAGE.

``run(mesh=...)`` accepts a reduced host mesh + scaled-down dims so CI can
lower the identical path on 4 mocked devices (tests/test_sharded_store.py).

Emits the same roofline record as the LM cells ->
benchmarks/results/dryrun/gnn-graphsage__train_1k__<mesh>.json
"""

import json
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.minibatch import DeviceBatch, LayerBlock, block_pad_sizes
from repro.featurestore import FeatureStore
from repro.launch import sharding as shlib
from repro.launch.mesh import cache_shard_axis, make_production_mesh
from repro.models import graphsage
from repro.optim.adam import AdamConfig, AdamW
from repro.roofline.analysis import collective_bytes_from_hlo, roofline_terms
from repro.configs.base import ShapeSpec

# paper Table 2: ogbn-papers100M; §4.1 setup
NUM_NODES = 111_059_956
FEAT_DIM = 128
NUM_CLASSES = 172
CACHE_FRAC = 0.01
BATCH = 1024     # paper uses 1000; padded to divide the 16-wide data axis
FANOUTS = (15, 10, 5)        # input-first (paper: 15,10,5 top-down)


def batch_structs(mesh, batch: int = BATCH, fanouts=FANOUTS,
                  feat_dim: int = FEAT_DIM):
    """ShapeDtypeStruct DeviceBatch + shardings (batch dims on 'data')."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    pads = block_pad_sizes(batch, fanouts)
    dp = shlib.batch_axes(mesh)     # () on a 1-D cache-only mesh -> replicate
    dp = dp if len(dp) > 1 else (dp[0] if dp else None)

    def sd(shape, dtype):
        return jax.ShapeDtypeStruct(shape, dtype)

    def sh(*parts):
        return NamedSharding(mesh, P(*parts))

    blocks, blocks_sh = [], []
    for li, (d, s) in enumerate(pads):
        k = fanouts[li]
        blocks.append(LayerBlock(
            nbr_idx=sd((d, k), jnp.int32), nbr_w=sd((d, k), jnp.float32),
            dst_mask=sd((d,), jnp.float32), num_src=s, num_dst=d))
        blocks_sh.append(LayerBlock(
            nbr_idx=sh(dp, None), nbr_w=sh(dp, None), dst_mask=sh(dp),
            num_src=s, num_dst=d))
    s0 = pads[0][1]
    batch_struct = DeviceBatch(
        blocks=tuple(blocks),
        input_cache_slots=sd((s0,), jnp.int32),
        input_streamed=sd((s0, feat_dim), jnp.float32),
        input_mask=sd((s0,), jnp.float32),
        labels=sd((batch,), jnp.int32),
        label_mask=sd((batch,), jnp.float32))
    batch_sh = DeviceBatch(
        blocks=tuple(blocks_sh),
        input_cache_slots=sh(dp),
        input_streamed=sh(dp, None),
        input_mask=sh(dp),
        labels=sh(dp),
        label_mask=sh(dp))
    return batch_struct, batch_sh


def placement_traffic_sim(cache_rows: int, n_shards: int, n_groups: int,
                          dominant_share: float = 0.8,
                          seed: int = 0) -> dict:
    """Cross-shard lookup traffic, contiguous vs locality, at paper |C|.

    Runs the REAL placement solver (``featurestore.placement``) on a
    synthetic Zipf demand histogram at full production cache size (1.11M
    rows on papers100M): each cached row's traffic is Zipf-distributed and
    ``dominant_share`` of it comes from one uniformly-drawn DP group — the
    skew Data Tiering (arXiv:2111.05894) reports for real access traces.
    Reports the fraction of hit traffic served by the requesting group's
    home shard under both placements.
    """
    from repro.featurestore.placement import home_shard, solve_placement

    rng = np.random.default_rng(seed)
    rows_per_shard = cache_rows // n_shards
    total = rng.zipf(1.5, cache_rows).astype(np.float64)
    dom = rng.integers(0, n_groups, cache_rows)
    # per-(group, row) traffic without materializing [G, R] for the metric:
    # dominant group carries dominant_share, the rest spread evenly
    rest = total * (1.0 - dominant_share) / max(n_groups - 1, 1)
    pref = np.array([home_shard(g, n_shards) for g in range(n_groups)])[dom]

    # contiguous: shard of a slot is slot // rows_per_shard (membership is
    # traffic-agnostic, so hot rows land uniformly across shards)
    def local_traffic(shard_of_slot):
        local = np.zeros(cache_rows)
        for g in range(n_groups):
            mine = dom == g
            share = np.where(mine, dominant_share * total, rest)
            local += share * (shard_of_slot == home_shard(g, n_shards))
        return float(local.sum())

    grand = float(total.sum())
    contiguous = np.arange(cache_rows) // rows_per_shard
    # locality: the real greedy solver on (total, preferred shard) — the
    # exact code path FeatureStore._solve_placement runs, via the same
    # internal assignment
    from repro.featurestore.placement import _assign
    locality, _ = _assign(total, pref, n_shards, rows_per_shard, seed=seed)
    frac_cont = local_traffic(contiguous) / grand
    frac_loc = local_traffic(locality) / grand
    return {
        "lookup_local_frac_contiguous": round(frac_cont, 4),
        "lookup_local_frac_locality": round(frac_loc, 4),
        "crossshard_rows_frac_contiguous": round(1 - frac_cont, 4),
        "crossshard_rows_frac_locality": round(1 - frac_loc, 4),
    }


def run(multi_pod: bool = False, *, mesh=None, num_nodes: int = NUM_NODES,
        feat_dim: int = FEAT_DIM, num_classes: int = NUM_CLASSES,
        cache_frac: float = CACHE_FRAC, batch: int = BATCH,
        fanouts=FANOUTS, hidden_dim: int = 256,
        input_impl: str = "fused", local_fast_path: bool = False) -> dict:
    """Lower + compile the GNS train step; ``mesh=None`` = production mesh.

    The reduced-dims path (explicit ``mesh`` + small shapes) is the CI
    lane: the same lowering on a mocked multi-device host mesh.
    ``local_fast_path=True`` lowers the step with the locality fast path
    active (``local_shard=0``): the input layer's cache-axis all-reduce is
    replaced by the recursive-doubling broadcast, which shows up directly
    in the compiled HLO's collective bytes.
    """
    if mesh is None:
        mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size
    cache_axis = cache_shard_axis(mesh)
    mcfg = graphsage.SageConfig(feat_dim=feat_dim, hidden_dim=hidden_dim,
                                num_classes=num_classes, num_layers=len(fanouts),
                                input_impl=input_impl,
                                input_kernel="reference",
                                cache_shard_axis=cache_axis)
    opt = AdamW(AdamConfig(lr=3e-3))
    # device-tier shape via the feature-store facade (pads rows so the
    # cache-axis shards divide evenly — the pod-scale cache tier)
    n_shards = mesh.shape[cache_axis]
    cache_rows = FeatureStore.padded_rows(num_nodes, cache_frac,
                                          multiple=n_shards)

    from jax.sharding import NamedSharding, PartitionSpec as P
    p_structs = jax.eval_shape(
        lambda: graphsage.init_params(jax.random.PRNGKey(0), mcfg))
    p_sh = jax.tree_util.tree_map(
        lambda _: NamedSharding(mesh, P()), p_structs)     # tiny -> replicated
    o_structs = jax.eval_shape(opt.init, p_structs)
    o_sh = {"m": p_sh, "v": p_sh, "step": NamedSharding(mesh, P())}
    cache_struct = jax.ShapeDtypeStruct((cache_rows, feat_dim), jnp.float32)
    cache_sh = NamedSharding(mesh, P(cache_axis, None))    # row-sharded cache
    b_structs, b_sh = batch_structs(mesh, batch, fanouts, feat_dim)

    local_shard = 0 if local_fast_path else None

    def train_step(params, opt_state, batch, cache_table):
        (loss, acc), grads = jax.value_and_grad(
            graphsage.loss_fn, has_aux=True)(params, batch, cache_table,
                                             mcfg, local_shard)
        params, opt_state = opt.update(grads, opt_state, params)
        return params, opt_state, loss

    t0 = time.time()
    with shlib.use_mesh(mesh):
        lowered = jax.jit(
            train_step,
            in_shardings=(p_sh, o_sh, b_sh, cache_sh),
            out_shardings=(p_sh, o_sh, NamedSharding(mesh, P()))).lower(
                p_structs, o_structs, b_structs, cache_struct)
        compiled = lowered.compile()
    t_compile = time.time() - t0

    cost_list = compiled.cost_analysis()
    cost = cost_list[0] if isinstance(cost_list, (list, tuple)) else cost_list
    coll = collective_bytes_from_hlo(compiled.as_text())
    try:
        mem = compiled.memory_analysis()
        mem_d = {"argument_bytes": getattr(mem, "argument_size_in_bytes", None),
                 "temp_bytes": getattr(mem, "temp_size_in_bytes", None)}
    except Exception as e:
        mem_d = {"error": str(e)}

    # roofline: no scan in the 3-layer GNN -> cost_analysis is exact
    n_params = sum(np.prod(l.shape) for l in jax.tree_util.tree_leaves(p_structs))
    flops = float(cost.get("flops", 0.0))
    byt = float(cost.get("bytes accessed", 0.0))
    shape = ShapeSpec("train_1k", 1, batch, "train")   # D = batch target nodes
    terms = roofline_terms(flops, byt, coll, _gnn_cfg_stub(), shape, chips,
                           n_active=float(n_params))
    table_bytes = cache_rows * feat_dim * 4
    # cross-shard lookup traffic before/after the locality placement map:
    # the real solver on a skewed synthetic demand at this config's |C|
    n_dp_groups = max(chips // n_shards, 1)
    placement_sim = placement_traffic_sim(cache_rows, n_shards,
                                          min(n_dp_groups, 64))
    s0_rows = block_pad_sizes(batch, fanouts)[0][1]
    row_bytes = feat_dim * 4
    rec = {
        "arch": "gnn-graphsage-gns", "shape": "train_1k",
        "mesh": "x".join(str(mesh.shape[a]) for a in mesh.axis_names),
        "chips": chips,
        "status": "ok", "kind": "train",
        "input_impl": mcfg.input_impl, "cache_shard_axis": cache_axis,
        "local_fast_path": bool(local_fast_path),
        "params_total": float(n_params),
        "cache_rows": cache_rows,
        "cache_bytes_per_chip": table_bytes / n_shards,
        # per-generation refresh transfer: shard-aware upload vs replicating
        # the full table to every chip (the paper-scale saving PR 2 landed)
        "upload_bytes_per_gen_sharded": table_bytes * chips // n_shards,
        "upload_bytes_per_gen_replicated": table_bytes * chips,
        # locality placement: fraction of cache-hit rows the requesting DP
        # group's home shard serves, and the implied cross-shard row bytes
        # per batch, contiguous vs locality (PR 3's saving)
        **placement_sim,
        "crossshard_bytes_per_batch_contiguous": int(
            s0_rows * row_bytes *
            placement_sim["crossshard_rows_frac_contiguous"]),
        "crossshard_bytes_per_batch_locality": int(
            s0_rows * row_bytes *
            placement_sim["crossshard_rows_frac_locality"]),
        "memory_analysis": mem_d,
        "cost_flops_per_device": flops, "cost_bytes_per_device": byt,
        "roofline": terms.as_dict(), "compile_s": round(t_compile, 2),
    }
    return rec


def _gnn_cfg_stub():
    """Minimal cfg for roofline_terms' model_flops (n_active overrides)."""
    from repro.configs.base import ArchConfig
    return ArchConfig(name="gnn", family="gnn", num_layers=3, d_model=256,
                      num_heads=1, num_kv_heads=1, d_ff=0, vocab_size=1)


def main():
    from pathlib import Path
    outdir = Path(__file__).resolve().parents[3] / "benchmarks" / "results" / "dryrun"
    outdir.mkdir(parents=True, exist_ok=True)
    failures = 0
    for mp in (False, True):
        rec = run(multi_pod=mp)
        name = f"gnn-graphsage__train_1k__{'multi' if mp else 'single'}.json"
        (outdir / name).write_text(json.dumps(rec, indent=1))
        r = rec["roofline"]
        print(f"[gnn {rec['mesh']}] dominant={r['dominant']} "
              f"compute={r['compute_s']:.5f}s memory={r['memory_s']:.5f}s "
              f"collective={r['collective_s']:.5f}s "
              f"cache/chip={rec['cache_bytes_per_chip']/1e6:.1f}MB "
              f"upload/gen={rec['upload_bytes_per_gen_sharded']/1e9:.2f}GB "
              f"(vs {rec['upload_bytes_per_gen_replicated']/1e9:.2f}GB repl.) "
              f"local-hit={rec['lookup_local_frac_locality']:.2f} "
              f"(vs {rec['lookup_local_frac_contiguous']:.2f} contiguous) "
              f"(compile {rec['compile_s']}s)")
    return failures


if __name__ == "__main__":
    sys.exit(main())

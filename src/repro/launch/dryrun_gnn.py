import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")
# ^ before any jax import (same contract as launch/dryrun.py)

"""Pod-scale dry-run of the PAPER's workload: GraphSAGE + GNS.

The 40 LM cells prove the framework; this proves the paper's own technique
at pod scale: the GNS ENGINE train step (``repro.gns.engine.make_train_step``
— byte-for-byte the function ``GNSEngine`` jits in process) lowered on the
16x16 (and 2x16x16) production mesh at ogbn-papers100M dimensions:

  * cache table [|C| = 1% of 111M = 1.11M rows, 128 feats] — row-sharded
    over the cache axis ('model'), refreshed by SHARD-AWARE upload;
  * minibatch: global batch 1024 = one minibatch per DP group, collated
    group-first (``gns.engine.collate_groups``'s layout), padded input layer
    of ~1.08M rows/step sharded over 'data';
  * input path: ``SageConfig(input_impl="fused")`` with the DEVICE-RESIDENT
    per-group home-shard vector — one compiled step serving any mix of
    locality fast paths at DP = 16 without retracing (the engine's regime);
  * train step = forward + backward + AdamW on the 3-layer GraphSAGE.

All the machinery lives in :mod:`repro.gns.describe` (``GNSEngine.describe``
reports the same record for an in-process config); this module keeps the
production dimensions, the CLI, and the CI-reduced ``run(mesh=...)`` entry.
``--diff A B`` (preset names or EngineConfig-JSON paths) prints the
describe diff mode instead: declarative fields + lowering/traffic records.

Emits the same roofline record as the LM cells ->
benchmarks/results/dryrun/gnn-graphsage__train_1k__<mesh>.json
"""

import json
import sys

from repro.gns.describe import (batch_structs, describe_lowering,   # noqa: F401
                                diff, placement_traffic_sim)
from repro.launch.mesh import make_production_mesh

# paper Table 2: ogbn-papers100M; §4.1 setup
NUM_NODES = 111_059_956
FEAT_DIM = 128
NUM_CLASSES = 172
CACHE_FRAC = 0.01
BATCH = 1024     # paper uses 1000; padded to divide the 16-wide data axis
FANOUTS = (15, 10, 5)        # input-first (paper: 15,10,5 top-down)


def run(multi_pod: bool = False, *, mesh=None, num_nodes: int = NUM_NODES,
        feat_dim: int = FEAT_DIM, num_classes: int = NUM_CLASSES,
        cache_frac: float = CACHE_FRAC, batch: int = BATCH,
        fanouts=FANOUTS, hidden_dim: int = 256,
        input_impl: str = "fused", fast_path: str = "dynamic") -> dict:
    """Lower + compile the engine train step; ``mesh=None`` = production mesh.

    The reduced-dims path (explicit ``mesh`` + small shapes) is the CI
    lane: the same lowering on a mocked multi-device host mesh.
    ``fast_path``: "dynamic" (default — the engine's home-shard vector),
    "static" (the PR-3 static-arg lowering, for HLO comparison) or "off"
    (plain per-shard psum, no locality gate).
    """
    if mesh is None:
        mesh = make_production_mesh(multi_pod=multi_pod)
    return describe_lowering(
        mesh=mesh, num_nodes=num_nodes, feat_dim=feat_dim,
        num_classes=num_classes, cache_frac=cache_frac, batch=batch,
        fanouts=tuple(fanouts), hidden_dim=hidden_dim,
        input_impl=input_impl, input_kernel="reference",
        fast_path=fast_path)


def _load_config(spec: str):
    """A preset name (``quickstart``) or a path to an EngineConfig JSON."""
    from pathlib import Path

    from repro.gns import EngineConfig, PRESETS
    if spec in PRESETS:
        return EngineConfig.preset(spec)
    return EngineConfig.from_dict(json.loads(Path(spec).read_text()))


def main_diff(spec_a: str, spec_b: str) -> int:
    """``--diff A B``: the describe() diff mode — compare two configs'
    declarative fields and their lowering/traffic records.  Exit status
    follows ``diff(1)`` convention: 0 = identical, 1 = they differ."""
    rec = diff(_load_config(spec_a), _load_config(spec_b))
    print(json.dumps(rec, indent=1, default=str))
    return 0 if rec["same"] else 1


def main():
    from pathlib import Path
    outdir = Path(__file__).resolve().parents[3] / "benchmarks" / "results" / "dryrun"
    outdir.mkdir(parents=True, exist_ok=True)
    failures = 0
    for mp in (False, True):
        rec = run(multi_pod=mp)
        name = f"gnn-graphsage__train_1k__{'multi' if mp else 'single'}.json"
        (outdir / name).write_text(json.dumps(rec, indent=1))
        r = rec["roofline"]
        print(f"[gnn {rec['mesh']}] dominant={r['dominant']} "
              f"compute={r['compute_s']:.5f}s memory={r['memory_s']:.5f}s "
              f"collective={r['collective_s']:.5f}s "
              f"dp_groups={rec['dp_groups']} fast_path={rec['fast_path']} "
              f"cache/chip={rec['cache_bytes_per_chip']/1e6:.1f}MB "
              f"upload/gen={rec['upload_bytes_per_gen_sharded']/1e9:.2f}GB "
              f"(vs {rec['upload_bytes_per_gen_replicated']/1e9:.2f}GB repl.) "
              f"local-hit={rec['lookup_local_frac_locality']:.2f} "
              f"(vs {rec['lookup_local_frac_contiguous']:.2f} contiguous) "
              f"(compile {rec['compile_s']}s)")
    return failures


if __name__ == "__main__":
    if "--diff" in sys.argv:
        i = sys.argv.index("--diff")
        if len(sys.argv) < i + 3:
            print("usage: dryrun_gnn.py --diff <preset|config.json> "
                  "<preset|config.json>", file=sys.stderr)
            sys.exit(2)
        sys.exit(main_diff(sys.argv[i + 1], sys.argv[i + 2]))
    sys.exit(main())

import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")
# ^ before any jax import (same contract as launch/dryrun.py)

"""Pod-scale dry-run of the PAPER's workload: GraphSAGE + GNS.

The 40 LM cells prove the framework; this proves the paper's own technique
at pod scale: the GNS train step — device cache table + padded minibatch
blocks + importance-weighted aggregation — lowered on the 16x16 (and
2x16x16) production mesh at ogbn-papers100M dimensions:

  * cache table [|C| = 1% of 111M = 1.11M rows, 128 feats] — row-sharded
    over 'model' (the pod-scale cache the paper's single T4 cannot hold);
  * minibatch: batch 1000, fanouts (15,10,5) => padded input layer of
    176k nodes/batch, sharded over 'data' (one minibatch per data group is
    the paper's multi-GPU regime);
  * train step = forward + backward + AdamW on the 3-layer GraphSAGE.

Emits the same roofline record as the LM cells ->
benchmarks/results/dryrun/gnn-graphsage__train_1k__<mesh>.json
"""

import json
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.minibatch import DeviceBatch, LayerBlock, block_pad_sizes
from repro.featurestore import FeatureStore
from repro.launch import sharding as shlib
from repro.launch.mesh import make_production_mesh
from repro.models import graphsage
from repro.optim.adam import AdamConfig, AdamW
from repro.roofline.analysis import collective_bytes_from_hlo, roofline_terms
from repro.configs.base import ShapeSpec

# paper Table 2: ogbn-papers100M; §4.1 setup
NUM_NODES = 111_059_956
FEAT_DIM = 128
NUM_CLASSES = 172
CACHE_FRAC = 0.01
BATCH = 1024     # paper uses 1000; padded to divide the 16-wide data axis
FANOUTS = (15, 10, 5)        # input-first (paper: 15,10,5 top-down)


def batch_structs(mesh):
    """ShapeDtypeStruct DeviceBatch + shardings (batch dims on 'data')."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    pads = block_pad_sizes(BATCH, FANOUTS)
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    dp = dp if len(dp) > 1 else dp[0]

    def sd(shape, dtype):
        return jax.ShapeDtypeStruct(shape, dtype)

    def sh(*parts):
        return NamedSharding(mesh, P(*parts))

    blocks, blocks_sh = [], []
    for li, (d, s) in enumerate(pads):
        k = FANOUTS[li]
        blocks.append(LayerBlock(
            nbr_idx=sd((d, k), jnp.int32), nbr_w=sd((d, k), jnp.float32),
            dst_mask=sd((d,), jnp.float32), num_src=s, num_dst=d))
        blocks_sh.append(LayerBlock(
            nbr_idx=sh(dp, None), nbr_w=sh(dp, None), dst_mask=sh(dp),
            num_src=s, num_dst=d))
    s0 = pads[0][1]
    batch = DeviceBatch(
        blocks=tuple(blocks),
        input_cache_slots=sd((s0,), jnp.int32),
        input_streamed=sd((s0, FEAT_DIM), jnp.float32),
        input_mask=sd((s0,), jnp.float32),
        labels=sd((BATCH,), jnp.int32),
        label_mask=sd((BATCH,), jnp.float32))
    batch_sh = DeviceBatch(
        blocks=tuple(blocks_sh),
        input_cache_slots=sh(dp),
        input_streamed=sh(dp, None),
        input_mask=sh(dp),
        labels=sh(dp),
        label_mask=sh(dp))
    return batch, batch_sh


def run(multi_pod: bool = False) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size
    mcfg = graphsage.SageConfig(feat_dim=FEAT_DIM, hidden_dim=256,
                                num_classes=NUM_CLASSES, num_layers=3)
    opt = AdamW(AdamConfig(lr=3e-3))
    # device-tier shape via the feature-store facade (pads rows so the
    # 'model'-axis shards divide evenly — the pod-scale cache tier)
    cache_rows = FeatureStore.padded_rows(NUM_NODES, CACHE_FRAC,
                                          multiple=mesh.shape["model"])

    from jax.sharding import NamedSharding, PartitionSpec as P
    p_structs = jax.eval_shape(
        lambda: graphsage.init_params(jax.random.PRNGKey(0), mcfg))
    p_sh = jax.tree_util.tree_map(
        lambda _: NamedSharding(mesh, P()), p_structs)     # tiny -> replicated
    o_structs = jax.eval_shape(opt.init, p_structs)
    o_sh = {"m": p_sh, "v": p_sh, "step": NamedSharding(mesh, P())}
    cache_struct = jax.ShapeDtypeStruct((cache_rows, FEAT_DIM), jnp.float32)
    cache_sh = NamedSharding(mesh, P("model", None))       # row-sharded cache
    b_structs, b_sh = batch_structs(mesh)

    def train_step(params, opt_state, batch, cache_table):
        (loss, acc), grads = jax.value_and_grad(
            graphsage.loss_fn, has_aux=True)(params, batch, cache_table, mcfg)
        params, opt_state = opt.update(grads, opt_state, params)
        return params, opt_state, loss

    t0 = time.time()
    with shlib.use_mesh(mesh):
        lowered = jax.jit(
            train_step,
            in_shardings=(p_sh, o_sh, b_sh, cache_sh),
            out_shardings=(p_sh, o_sh, NamedSharding(mesh, P()))).lower(
                p_structs, o_structs, b_structs, cache_struct)
        compiled = lowered.compile()
    t_compile = time.time() - t0

    cost_list = compiled.cost_analysis()
    cost = cost_list[0] if isinstance(cost_list, (list, tuple)) else cost_list
    coll = collective_bytes_from_hlo(compiled.as_text())
    try:
        mem = compiled.memory_analysis()
        mem_d = {"argument_bytes": getattr(mem, "argument_size_in_bytes", None),
                 "temp_bytes": getattr(mem, "temp_size_in_bytes", None)}
    except Exception as e:
        mem_d = {"error": str(e)}

    # roofline: no scan in the 3-layer GNN -> cost_analysis is exact
    n_params = sum(np.prod(l.shape) for l in jax.tree_util.tree_leaves(p_structs))
    flops = float(cost.get("flops", 0.0))
    byt = float(cost.get("bytes accessed", 0.0))
    shape = ShapeSpec("train_1k", 1, BATCH, "train")   # D = BATCH target nodes
    terms = roofline_terms(flops, byt, coll, _gnn_cfg_stub(), shape, chips,
                           n_active=float(n_params))
    rec = {
        "arch": "gnn-graphsage-gns", "shape": "train_1k",
        "mesh": "2x16x16" if multi_pod else "16x16", "chips": chips,
        "status": "ok", "kind": "train",
        "params_total": float(n_params),
        "cache_rows": cache_rows,
        "cache_bytes_per_chip": cache_rows * FEAT_DIM * 4 / mesh.shape["model"],
        "memory_analysis": mem_d,
        "cost_flops_per_device": flops, "cost_bytes_per_device": byt,
        "roofline": terms.as_dict(), "compile_s": round(t_compile, 2),
    }
    return rec


def _gnn_cfg_stub():
    """Minimal cfg for roofline_terms' model_flops (n_active overrides)."""
    from repro.configs.base import ArchConfig
    return ArchConfig(name="gnn", family="gnn", num_layers=3, d_model=256,
                      num_heads=1, num_kv_heads=1, d_ff=0, vocab_size=1)


def main():
    from pathlib import Path
    outdir = Path(__file__).resolve().parents[3] / "benchmarks" / "results" / "dryrun"
    outdir.mkdir(parents=True, exist_ok=True)
    failures = 0
    for mp in (False, True):
        rec = run(multi_pod=mp)
        name = f"gnn-graphsage__train_1k__{'multi' if mp else 'single'}.json"
        (outdir / name).write_text(json.dumps(rec, indent=1))
        r = rec["roofline"]
        print(f"[gnn {'2x16x16' if mp else '16x16'}] dominant={r['dominant']} "
              f"compute={r['compute_s']:.5f}s memory={r['memory_s']:.5f}s "
              f"collective={r['collective_s']:.5f}s "
              f"cache/chip={rec['cache_bytes_per_chip']/1e6:.1f}MB "
              f"(compile {rec['compile_s']}s)")
    return failures


if __name__ == "__main__":
    sys.exit(main())

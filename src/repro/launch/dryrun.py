import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST be the first two lines: jax locks the device count on first init.
# The 512 placeholder host devices exist ONLY in this process — smoke tests
# and benchmarks see the real 1-device CPU.

"""Multi-pod dry-run (deliverable e).

For every (architecture x input shape) cell, build the production mesh
(single-pod 16x16 = 256 chips, multi-pod 2x16x16 = 512 chips), lower the
REAL train_step / serve_step with the production in/out shardings against
ShapeDtypeStruct inputs (zero allocation), ``.compile()`` it, and record:

  * memory_analysis()      — proof the cell fits (bytes per device),
  * cost_analysis()        — FLOPs / bytes for the roofline (§Roofline),
  * collective bytes       — parsed from the post-SPMD HLO text,
  * the 3-term roofline    — repro/roofline/analysis.py.

Results are written incrementally to benchmarks/results/dryrun/<cell>.json
so an interrupted sweep resumes.  Failures (sharding mismatch, OOM at
compile, unsupported collective) are bugs in the system — the sweep reports
them and exits nonzero.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-7b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multipod-only|--single-only]
"""
# (no `from __future__ import annotations`: the XLA_FLAGS lines must be first)

import argparse
import json
import sys
import time
import traceback
from pathlib import Path

import dataclasses

import jax
import jax.numpy as jnp

from repro.configs import SHAPES, get_config, list_archs, shape_applicable
from repro.launch import sharding as shlib
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import input_specs
from repro.launch.steps import (make_prefill_step, make_serve_step,
                                make_train_step)
from repro.models import scan_util
from repro.models.lm import get_model
from repro.optim.adam import AdamConfig, AdamW
from repro.roofline.analysis import collective_bytes_from_hlo, roofline_terms

RESULTS_DIR = Path(__file__).resolve().parents[3] / "benchmarks" / "results" / "dryrun"


def _leaf_count(structs) -> float:
    return float(sum(
        int(jnp.prod(jnp.array(l.shape))) if l.shape else 1
        for l in jax.tree_util.tree_leaves(structs)))


def _param_counts(structs, cfg) -> tuple[float, float]:
    """(total, active) param counts from eval_shape structs (exact)."""
    total = expert = 0.0

    def visit(kp, l):
        nonlocal total, expert
        n = 1.0
        for s in l.shape:
            n *= s
        total += n
        path = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in kp)
        if "experts_" in path:
            expert += n

    jax.tree_util.tree_map_with_path(visit, structs)
    active = total
    if cfg.moe is not None and expert:
        active = total - expert * (1.0 - cfg.moe.top_k / cfg.moe.num_experts)
    return total, active


def _sharded_bytes(structs, shardings, mesh) -> float:
    """Per-device bytes of a struct pytree under its shardings."""
    total = 0.0
    for l, sh in zip(jax.tree_util.tree_leaves(structs),
                     jax.tree_util.tree_leaves(
                         shardings, is_leaf=lambda x: hasattr(x, "spec"))):
        n = l.dtype.itemsize
        for dim in l.shape:
            n *= dim
        shard = 1
        for part in sh.spec:
            if part is None:
                continue
            axes = (part,) if isinstance(part, str) else part
            for a in axes:
                shard *= mesh.shape[a]
        total += n / shard
    return total


# ---------------------------------------------------------------------------
# cost probes
# ---------------------------------------------------------------------------
# XLA cost_analysis counts a while(scan) body once (models/scan_util.py), so
# costs come from UNROLLED probe compiles.  Stacks are per-layer homogeneous,
# hence exactly affine in the probe unit u: cost(u) = a + g*u.  Two probes at
# small u recover (a, g); extrapolation to the real depth is exact.  Probes
# always run accum=1 at the full global batch (same total tokens — fwd/bwd
# cost is accum-invariant); the f32 accumulator's HBM traffic for accum>1 is
# added analytically (documented in EXPERIMENTS.md §Dry-run).

PROBE_FULL_MAX_LAYERS = 14          # full unroll below this; affine above


def _probe_plan(cfg):
    """(make_cfg(u), u1, u2, u_target) in affine units."""
    if cfg.encoder_layers > 0:
        # enc and dec depths are equal (12/12): unit scales both together
        def make(u):
            return dataclasses.replace(cfg, num_layers=u, encoder_layers=u,
                                       grad_accum=1)
        return make, 2, 4, cfg.num_layers
    if cfg.shared_attn_every:
        c = cfg.shared_attn_every

        def make(u):                 # unit = shared-attn group
            return dataclasses.replace(cfg, num_layers=u * c, grad_accum=1)
        return make, 1, 2, cfg.num_layers // c
    if cfg.xlstm is not None:
        def make(u):
            keep = tuple(i for i in cfg.xlstm.slstm_at if i < u)
            return dataclasses.replace(
                cfg, num_layers=u, grad_accum=1,
                xlstm=dataclasses.replace(cfg.xlstm, slstm_at=keep))
        return make, cfg.num_layers, cfg.num_layers, cfg.num_layers
    nd = cfg.moe.first_dense_layers if cfg.moe else 0

    def make(u):
        return dataclasses.replace(cfg, num_layers=u, grad_accum=1)
    if cfg.num_layers <= PROBE_FULL_MAX_LAYERS:
        return make, cfg.num_layers, cfg.num_layers, cfg.num_layers
    return make, nd + 2, nd + 4, cfg.num_layers


def _compile_probe(cfg, shape, mesh):
    """Unrolled compile of one probe cfg -> (flops, bytes, coll dict)."""
    model = get_model(cfg)
    with shlib.use_mesh(mesh), shlib.arch_scope(cfg), scan_util.unrolled():
        specs = input_specs(cfg, shape, mesh, model=model)
        p_structs, p_sh = specs["params"]
        if shape.kind in ("decode", "prefill"):
            serve_step = (make_serve_step(model) if shape.kind == "decode"
                      else make_prefill_step(model))
            t_struct, t_sh = specs["tokens"]
            s_structs, s_sh = specs["state"]
            lowered = jax.jit(serve_step, in_shardings=(p_sh, t_sh, s_sh),
                              out_shardings=(t_sh, s_sh),
                              donate_argnums=(2,)).lower(
                                  p_structs, t_struct, s_structs)
        else:
            opt = AdamW(AdamConfig(lr=3e-4))
            train_step = make_train_step(model, opt)
            b_structs, b_sh = specs["batch"]
            o_structs = jax.eval_shape(opt.init, p_structs)
            o_sh = {"m": p_sh, "v": p_sh,
                    "step": jax.sharding.NamedSharding(
                        mesh, jax.sharding.PartitionSpec())}
            loss_sh = jax.sharding.NamedSharding(
                mesh, jax.sharding.PartitionSpec())
            lowered = jax.jit(train_step, in_shardings=(p_sh, o_sh, b_sh),
                              out_shardings=(p_sh, o_sh, loss_sh),
                              donate_argnums=(0, 1)).lower(
                                  p_structs, o_structs, b_structs)
        compiled = lowered.compile()
    cost_list = compiled.cost_analysis()
    cost = cost_list[0] if isinstance(cost_list, (list, tuple)) else cost_list
    coll = collective_bytes_from_hlo(compiled.as_text())
    return (float(cost.get("flops", 0.0)),
            float(cost.get("bytes accessed", 0.0)), coll)


def _affine_coll(c1, c2, w1, w2) -> dict:
    out = {}
    for k in c1:
        if k == "total":
            continue
        out[k] = {"bytes": max(int(w1 * c1[k]["bytes"] + w2 * c2[k]["bytes"]), 0),
                  "count": max(int(round(w1 * c1[k]["count"] + w2 * c2[k]["count"])), 0)}
    out["total"] = sum(v["bytes"] for v in out.values())
    return out


def probe_costs(cfg, shape, mesh) -> dict:
    """Two probe passes per unit: the reference pass gives FLOPs/collectives;
    the linear-attention-traffic pass (kernels/probe_ctx.py) gives 'bytes
    accessed' matching the flash kernel's HBM footprint instead of the
    reference softmax chain.  Skipped where identical (decode: single-token
    attention reads its cache for real; xlstm: no mha-based attention)."""
    from repro.kernels.probe_ctx import linear_attention_traffic

    make, u1, u2, u_t = _probe_plan(cfg)
    needs_linear = shape.kind != "decode" and cfg.xlstm is None

    def probe(u):
        f, b_ref, c = _compile_probe(make(u), shape, mesh)
        if needs_linear:
            with linear_attention_traffic():
                _, b_lin, _ = _compile_probe(make(u), shape, mesh)
            return f, b_lin, c
        return f, b_ref, c

    f1, b1, c1 = probe(u1)
    if u2 == u1:                     # full unroll — exact
        flops, byt, coll = f1, b1, c1
        mode = f"full_unroll(u={u1})"
    else:
        f2, b2, c2 = probe(u2)
        g = (u_t - u1) / (u2 - u1)   # cost(u) = p1 + (p2-p1)*g
        flops = f1 + (f2 - f1) * g
        byt = b1 + (b2 - b1) * g
        coll = _affine_coll(c1, c2, 1.0 - g, g)
        mode = f"affine(u1={u1},u2={u2},u={u_t})"
    accum = max(cfg.grad_accum, 1)
    accum_bytes = 0.0
    if shape.kind == "train" and accum > 1:
        # f32 grad accumulator read+write per extra microbatch, per chip
        n_per_chip = _probe_param_bytes_per_chip(cfg, mesh)
        accum_bytes = (accum - 1) * 2 * n_per_chip
        byt += accum_bytes
    return {"flops": flops, "bytes": byt, "coll": coll, "mode": mode,
            "accum_bytes_correction": accum_bytes}


def _probe_param_bytes_per_chip(cfg, mesh) -> float:
    model = get_model(cfg)
    with shlib.use_mesh(mesh), shlib.arch_scope(cfg):
        specs = input_specs(cfg, SHAPES["train_4k"], mesh, model=model)
        p_structs, p_sh = specs["params"]
    n = 0.0
    for l in jax.tree_util.tree_leaves(p_structs):
        c = 4.0                                     # f32 accumulator
        for d in l.shape:
            c *= d
        n += c
    return n / mesh.size                            # FSDP/TP sharded average


VARIANTS = {
    # §Perf hillclimb variants (EXPERIMENTS.md); applied by name with '+'
    "pure_dp": lambda c: dataclasses.replace(c, pure_dp=True, fsdp=True),
    "chunked_ce": lambda c: dataclasses.replace(c, chunked_ce=512),
    "mlstm_chunk": lambda c: dataclasses.replace(
        c, xlstm=dataclasses.replace(c.xlstm, chunk=256)),
    "accum4": lambda c: dataclasses.replace(c, grad_accum=4),
    "grad_cast": lambda c: dataclasses.replace(c, bf16_grad_stream=True),
    "bf16_moments": lambda c: c,     # moment dtype handled via CLI flag
}


def apply_variant(cfg, variant: str):
    for name in variant.split("+"):
        if name:
            cfg = VARIANTS[name](cfg)
    return cfg


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             opt_moment_dtype: str = "float32", probe: bool = True,
             variant: str = "") -> dict:
    cfg = apply_variant(get_config(arch), variant)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name,
                "mesh": "2x16x16" if multi_pod else "16x16",
                "status": "skipped", "reason": why}

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size
    model = get_model(cfg)
    t0 = time.time()

    with shlib.use_mesh(mesh), shlib.arch_scope(cfg):
        specs = input_specs(cfg, shape, mesh, model=model)
        p_structs, p_sh = specs["params"]
        n_total, n_active = _param_counts(p_structs, cfg)

        if shape.kind in ("decode", "prefill"):
            serve_step = (make_serve_step(model) if shape.kind == "decode"
                      else make_prefill_step(model))
            t_struct, t_sh = specs["tokens"]
            s_structs, s_sh = specs["state"]
            jitted = jax.jit(serve_step,
                             in_shardings=(p_sh, t_sh, s_sh),
                             out_shardings=(t_sh, s_sh),
                             donate_argnums=(2,))   # state updated in place
            lowered = jitted.lower(p_structs, t_struct, s_structs)
            arg_bytes = (_sharded_bytes(p_structs, p_sh, mesh)
                         + _sharded_bytes(s_structs, s_sh, mesh))
        else:
            mdt = jnp.bfloat16 if opt_moment_dtype == "bfloat16" else jnp.float32
            opt = AdamW(AdamConfig(lr=3e-4, moment_dtype=mdt))
            train_step = make_train_step(model, opt)
            b_structs, b_sh = specs["batch"]
            o_structs = jax.eval_shape(opt.init, p_structs)
            o_sh = {"m": p_sh, "v": p_sh,
                    "step": jax.sharding.NamedSharding(
                        mesh, jax.sharding.PartitionSpec())}
            loss_sh = jax.sharding.NamedSharding(
                mesh, jax.sharding.PartitionSpec())
            jitted = jax.jit(train_step,
                             in_shardings=(p_sh, o_sh, b_sh),
                             out_shardings=(p_sh, o_sh, loss_sh),
                             donate_argnums=(0, 1))  # params/opt in place
            lowered = jitted.lower(p_structs, o_structs, b_structs)
            arg_bytes = (_sharded_bytes(p_structs, p_sh, mesh)
                         + _sharded_bytes(o_structs, o_sh, mesh))

        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    # ---- artifacts -------------------------------------------------------
    try:
        mem = compiled.memory_analysis()
        mem_d = {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "generated_code_bytes": getattr(mem, "generated_code_size_in_bytes",
                                            None),
        }
    except Exception as e:                                   # CPU backend gaps
        mem_d = {"error": str(e)}
    hlo_len = len(compiled.as_text())
    del compiled, lowered, jitted

    # cost probes (unrolled; scan bodies fully counted — see scan_util).
    # The multi-pod pass is the shard/compile proof only (§Roofline is
    # single-pod), so probes are skipped there unless forced.
    t0p = time.time()
    probe_d = probe_costs(cfg, shape, mesh) if probe else None
    t_probe = time.time() - t0p
    terms = (roofline_terms(probe_d["flops"], probe_d["bytes"],
                            probe_d["coll"], cfg, shape, chips,
                            n_active=n_active) if probe else None)

    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": chips, "status": "ok",
        "kind": shape.kind,
        "grad_accum": cfg.grad_accum,
        "params_total": n_total, "params_active": n_active,
        "arg_bytes_per_device": arg_bytes,
        "memory_analysis": mem_d,
        "probe_mode": probe_d["mode"] if probe else "skipped(multipod)",
        "cost_flops_per_device": probe_d["flops"] if probe else None,
        "cost_bytes_per_device": probe_d["bytes"] if probe else None,
        "roofline": terms.as_dict() if probe else None,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "probe_s": round(t_probe, 2),
        "hlo_bytes": hlo_len,
    }
    return rec


def cell_path(arch: str, shape_name: str, multi_pod: bool,
              variant: str = "") -> Path:
    mesh = "multi" if multi_pod else "single"
    tag = f"__{variant.replace('+', '_')}" if variant else ""
    return RESULTS_DIR / f"{arch}__{shape_name}__{mesh}{tag}.json"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multipod", action="store_true",
                    help="run the 2x16x16 mesh (default: single-pod 16x16)")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--force", action="store_true", help="ignore cached cells")
    ap.add_argument("--moment-dtype", default=None,
                    help="override optimizer moment dtype (bfloat16 for MoE)")
    ap.add_argument("--variant", default="",
                    help="'+'-joined §Perf variant names (see VARIANTS)")
    args = ap.parse_args(argv)

    RESULTS_DIR.mkdir(parents=True, exist_ok=True)
    archs = [args.arch] if args.arch else list_archs()
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = [args.multipod] if not args.both_meshes else [False, True]

    failures = []
    for arch in archs:
        for shape_name in shapes:
            for mp in meshes:
                path = cell_path(arch, shape_name, mp, args.variant)
                if path.exists() and not args.force:
                    print(f"[cached] {path.name}")
                    continue
                label = f"{arch} x {shape_name} x {'2x16x16' if mp else '16x16'}"
                print(f"[run] {label}", flush=True)
                try:
                    mdt = args.moment_dtype or (
                        "bfloat16" if get_config(arch).fsdp else "float32")
                    rec = run_cell(arch, shape_name, mp, opt_moment_dtype=mdt,
                                   probe=not mp, variant=args.variant)
                    jax.clear_caches()
                except Exception:
                    failures.append(label)
                    rec = {"arch": arch, "shape": shape_name,
                           "mesh": "2x16x16" if mp else "16x16",
                           "variant": args.variant, "status": "failed",
                           "traceback": traceback.format_exc()}
                    print(rec["traceback"], file=sys.stderr)
                path.write_text(json.dumps(rec, indent=1))
                if rec["status"] == "ok" and rec.get("roofline"):
                    r = rec["roofline"]
                    print(f"  ok: dominant={r['dominant']} "
                          f"compute={r['compute_s']:.4f}s "
                          f"memory={r['memory_s']:.4f}s "
                          f"collective={r['collective_s']:.4f}s "
                          f"frac={r['roofline_fraction']:.3f} "
                          f"(lower {rec['lower_s']}s compile {rec['compile_s']}s)",
                          flush=True)
                elif rec["status"] == "ok":
                    print(f"  ok (compile proof only): "
                          f"lower {rec['lower_s']}s compile {rec['compile_s']}s",
                          flush=True)
                elif rec["status"] == "skipped":
                    print(f"  skipped: {rec['reason']}")
    if failures:
        print(f"\nFAILED cells ({len(failures)}):", *failures, sep="\n  ")
        return 1
    print("\nall requested cells passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())

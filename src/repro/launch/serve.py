"""Batched decode engine (the LM zoo's serving path).

Design (lockstep batched decoding):

* Requests are grouped into batches of ``max_batch`` by EXACT prompt length
  (the decode state keeps one scalar position for the whole batch — lockstep.
  Production engines left-pad + per-slot offsets / paged KV; exact-length
  grouping keeps the compiled step identical and is the documented
  simplification — DESIGN.md §4).
* One prefill call (decode_step over the S prompt tokens — fills the KV
  cache / recurrent state), then token-by-token greedy or temperature
  sampling; per-slot EOS tracking; a finished slot's tokens are ignored.
* The compiled step is cached per (batch, prompt_len bucket, cache_len) —
  steady-state serving reuses one executable.

Works for every family: attention archs carry KV caches, SSM/xLSTM carry
O(1) recurrent state, enc-dec prefills the encoder via ``prefill_encoder``.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import encdec
from repro.models.lm import ModelAPI, get_model


@dataclasses.dataclass
class Request:
    prompt: np.ndarray                 # [S] int32
    max_new_tokens: int = 16
    eos_id: int = -1                   # -1: never stops early


@dataclasses.dataclass
class Completion:
    tokens: np.ndarray                 # [<=max_new_tokens]
    prefill_s: float
    decode_s: float
    steps: int


class ServeEngine:
    def __init__(self, cfg: ArchConfig, params, max_batch: int = 8,
                 cache_margin: int = 64, rng_seed: int = 0,
                 temperature: float = 0.0):
        self.cfg = cfg
        self.model: ModelAPI = get_model(cfg)
        self.params = params
        self.max_batch = max_batch
        self.cache_margin = cache_margin
        self.temperature = temperature
        self._rng = jax.random.PRNGKey(rng_seed)
        self._step_cache: dict = {}

    # ------------------------------------------------------------------
    def _decode_fn(self):
        if "step" not in self._step_cache:
            model = self.model
            temp = self.temperature

            @jax.jit
            def step(params, tokens, state, key):
                logits, state = model.decode_step(params, tokens, state)
                if temp > 0.0:
                    nxt = jax.random.categorical(key, logits / temp, axis=-1)
                else:
                    nxt = jnp.argmax(logits, axis=-1)
                return nxt.astype(jnp.int32)[:, None], state

            self._step_cache["step"] = step
        return self._step_cache["step"]

    def _init_state(self, batch: int, cache_len: int, enc_len: int = 0):
        cfg = self.cfg
        if cfg.encoder_layers > 0:
            return self.model.decode_init(batch, cache_len, enc_len)
        if cfg.xlstm is not None:
            return self.model.decode_init(batch)
        return self.model.decode_init(batch, cache_len)

    # ------------------------------------------------------------------
    def generate_batch(self, requests: Sequence[Request],
                       frame_embeds: Optional[np.ndarray] = None
                       ) -> list[Completion]:
        """All requests must share a prompt length (exact-length batching)."""
        assert requests and len(requests) <= self.max_batch
        s = len(requests[0].prompt)
        assert all(len(r.prompt) == s for r in requests), \
            "exact-length batching: group requests by prompt length"
        b = len(requests)
        max_new = max(r.max_new_tokens for r in requests)
        cache_len = s + max_new + self.cache_margin

        enc_len = frame_embeds.shape[1] if frame_embeds is not None else 0
        state = self._init_state(b, cache_len, enc_len)
        if self.cfg.encoder_layers > 0:
            assert frame_embeds is not None, "enc-dec serving needs frames"
            state["cross"] = encdec.prefill_encoder(
                self.params, self.cfg, jnp.asarray(frame_embeds))

        prompts = jnp.asarray(np.stack([r.prompt for r in requests]), jnp.int32)
        step = self._decode_fn()
        self._rng, k = jax.random.split(self._rng)

        t0 = time.perf_counter()
        nxt, state = step(self.params, prompts, state, k)
        nxt.block_until_ready()
        prefill_s = time.perf_counter() - t0

        out = np.full((b, max_new), -1, np.int32)
        done = np.zeros(b, bool)
        steps = 0
        t0 = time.perf_counter()
        for i in range(max_new):
            cur = np.asarray(nxt)[:, 0]
            for j, r in enumerate(requests):
                if not done[j] and i < r.max_new_tokens:
                    out[j, i] = cur[j]
                    if cur[j] == r.eos_id or i + 1 >= r.max_new_tokens:
                        done[j] = True
            steps += 1
            if done.all():
                break
            self._rng, k = jax.random.split(self._rng)
            nxt, state = step(self.params, nxt, state, k)
        decode_s = time.perf_counter() - t0

        comps = []
        for j, r in enumerate(requests):
            toks = out[j][out[j] >= 0][: r.max_new_tokens]
            comps.append(Completion(tokens=toks, prefill_s=prefill_s,
                                    decode_s=decode_s, steps=steps))
        return comps

    def serve(self, requests: Sequence[Request], **kw) -> list[Completion]:
        """Group by prompt length, batch up to max_batch, run rounds."""
        by_len: dict[int, list[Request]] = {}
        order: dict[int, list[int]] = {}
        for i, r in enumerate(requests):
            by_len.setdefault(len(r.prompt), []).append(r)
            order.setdefault(len(r.prompt), []).append(i)
        results: list[Optional[Completion]] = [None] * len(requests)
        for L, group in by_len.items():
            idxs = order[L]
            for lo in range(0, len(group), self.max_batch):
                chunk = group[lo:lo + self.max_batch]
                comps = self.generate_batch(chunk, **kw)
                for k_i, c in zip(idxs[lo:lo + self.max_batch], comps):
                    results[k_i] = c
        return results  # type: ignore[return-value]

"""Logical-axis sharding rules (DP / TP / EP / SP) with divisibility fallback.

Model code annotates activations/params with *logical* axes:

    x = constrain(x, "batch", "seq", None)      # activations
    spec = param_spec(path, shape)               # parameters (rule table)

and this module maps logical -> physical mesh axes:

    batch  -> ('pod', 'data')     data parallel (pods are extra DP)
    model  -> 'model'             tensor/expert parallel
    expert -> 'model'             MoE expert parallel (same axis as TP)
    seq    -> 'data'              sequence parallel (long-context decode only,
                                  applied when batch can't fill 'data')
    None   -> replicated

Divisibility fallback: a logical axis whose dimension does not divide by the
physical axis size is silently replicated (e.g. xlstm-125m has 4 heads on a
model=16 axis -> heads replicate, its 1536-wide inner dim still shards).
This is what makes ONE rule table serve architectures from 125M to 480B.

`current_mesh()` is a context set by the launcher / dry-run; with no mesh in
scope every constraint is a no-op, so smoke tests on 1 CPU device run the
exact same model code.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional, Sequence, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def shard_map_compat(body, *, mesh, in_specs, out_specs):
    """Call shard_map across jax version bands: 0.4.x ships it under
    jax.experimental with check_rep; newer jax exposes jax.shard_map whose
    replication-check kwarg migrated check_rep -> check_vma.  Dispatch on
    the actual signature, not the version."""
    import inspect

    if hasattr(jax, "shard_map"):
        fn = jax.shard_map
    else:
        from jax.experimental.shard_map import shard_map as fn
    try:
        params = inspect.signature(fn).parameters
    except (TypeError, ValueError):          # C-level / wrapped callable
        params = None
    if params is not None:
        if "check_vma" in params:
            kw = {"check_vma": False}
        elif "check_rep" in params:
            kw = {"check_rep": False}
        else:
            kw = {}
        return fn(body, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)
    # unreadable signature: still try to DISABLE the replication check (the
    # bodies here rely on it being off) before falling back to defaults
    for kw in ({"check_vma": False}, {"check_rep": False}, {}):
        try:
            return fn(body, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, **kw)
        except TypeError:
            if not kw:
                raise
    raise AssertionError("unreachable")

_LOGICAL_TO_PHYSICAL = {
    "batch": ("pod", "data"),
    "model": ("model",),
    "expert": ("model",),
    "seq": ("data",),
    "attn_sq": ("model",),     # seq-sharded attention (heads % tp != 0 path)
    "cache": ("model",),       # feature-store device-table rows (GNS cache
                               # sharding rides the TP axis — mesh.py §roles)
    "pod": ("pod",),
    "data": ("data",),
}

_state = threading.local()


def current_mesh() -> Optional[Mesh]:
    return getattr(_state, "mesh", None)


def logical_table() -> dict:
    return {**_LOGICAL_TO_PHYSICAL, **getattr(_state, "overrides", {})}


@contextlib.contextmanager
def logical_overrides(**kw):
    """Remap logical axes for a scope (e.g. pure-DP: batch spans all axes)."""
    prev = getattr(_state, "overrides", {})
    _state.overrides = {**prev, **kw}
    try:
        yield
    finally:
        _state.overrides = prev


@contextlib.contextmanager
def arch_scope(cfg):
    """Per-arch distribution scope.  pure_dp (§Perf): the whole mesh is data
    parallelism (batch -> pod x data x model), TP/EP disabled, parameters
    ZeRO-3-sharded over everything (see param_sharding fsdp_axes)."""
    if getattr(cfg, "pure_dp", False):
        assert cfg.moe is None, "pure_dp is invalid for MoE archs (EP needs 'model')"
        with logical_overrides(batch=("pod", "data", "model"),
                               model=(), expert=(), attn_sq=(), seq=()):
            yield
    else:
        yield


def batch_axes(mesh: Mesh) -> tuple:
    """Mesh axes carrying the logical batch (override-aware)."""
    return tuple(a for a in logical_table()["batch"] if a in mesh.axis_names)


@contextlib.contextmanager
def use_mesh(mesh: Optional[Mesh]):
    """Thread-local mesh scope. ``constrain``/``sharding_for`` build explicit
    NamedShardings from it, so no jax-global ambient mesh is needed."""
    prev = current_mesh()
    _state.mesh = mesh
    try:
        yield mesh
    finally:
        _state.mesh = prev


def _physical_axes(mesh: Mesh, logical: Optional[str], dim: int):
    """Resolve one logical axis -> tuple of mesh axes that divide `dim`."""
    if logical is None:
        return None
    axes = [a for a in logical_table().get(logical, ()) if a in mesh.axis_names]
    if not axes:
        return None
    total = 1
    for a in axes:
        total *= mesh.shape[a]
    if dim % total != 0:
        # fallback: try a prefix of the axes, else replicate
        keep = []
        prod = 1
        for a in axes:
            if dim % (prod * mesh.shape[a]) == 0:
                keep.append(a)
                prod *= mesh.shape[a]
            else:
                break
        if not keep:
            return None
        return tuple(keep)
    return tuple(axes)


def spec_for(mesh: Mesh, logical_axes: Sequence[Optional[str]],
             shape: Sequence[int], *, unconstrained_fallback: bool = False) -> P:
    """Logical -> physical PartitionSpec.

    ``unconstrained_fallback=True`` (activation constraints): dims whose
    logical axis is None or fails divisibility become UNCONSTRAINED, letting
    GSPMD propagate from the (always shardable) weights.  A hard None here
    would mean "replicate", which forces an all-gather whenever a head count
    does not divide the axis (e.g. qwen2's 28 heads on model=16) — measured
    as a per-layer collective storm in EXPERIMENTS.md §Perf iteration 0.
    In/out shardings (in_shardings must be concrete) keep None = replicated.
    """
    assert len(logical_axes) == len(shape), (logical_axes, shape)
    used: set = set()
    parts = []
    fallback = P.UNCONSTRAINED if unconstrained_fallback else None
    for name, dim in zip(logical_axes, shape):
        ax = _physical_axes(mesh, name, dim)
        if ax is not None and any(a in used for a in ax):
            ax = None                       # each mesh axis used at most once
        if ax is not None:
            used.update(ax)
            parts.append(ax if len(ax) > 1 else ax[0])
        else:
            parts.append(fallback)
    return P(*parts)


def constrain(x: jax.Array, *logical_axes: Optional[str]) -> jax.Array:
    """with_sharding_constraint by logical axes; no-op without a mesh."""
    mesh = current_mesh()
    if mesh is None:
        return x
    spec = spec_for(mesh, logical_axes, x.shape, unconstrained_fallback=True)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def constrain_hard(x: jax.Array, *logical_axes: Optional[str]) -> jax.Array:
    """Like constrain, but None / failed axes mean REPLICATED (hard).

    Used where GSPMD free choice is known-bad: e.g. the seq-sharded attention
    path must keep dh and Sk unsharded or backward grows partial-sum
    all-reduces of [B,H,S,S] score gradients (§Perf iteration 0)."""
    mesh = current_mesh()
    if mesh is None:
        return x
    spec = spec_for(mesh, logical_axes, x.shape, unconstrained_fallback=False)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def axis_size(name: str) -> int:
    """Size of a mesh axis in the current scope (1 if absent / no mesh)."""
    mesh = current_mesh()
    if mesh is None or name not in mesh.axis_names:
        return 1
    return mesh.shape[name]


def sharding_for(x_shape: Sequence[int], *logical_axes: Optional[str],
                 mesh: Optional[Mesh] = None) -> Optional[NamedSharding]:
    mesh = mesh or current_mesh()
    if mesh is None:
        return None
    return NamedSharding(mesh, spec_for(mesh, logical_axes, x_shape))


# ---------------------------------------------------------------------------
# Parameter rule table (name-suffix based)
# ---------------------------------------------------------------------------
# Model params are plain nested dicts with conventional leaf names; the rules
# below map a leaf's path suffix to logical axes (Megatron-style TP):
#   column-parallel ("in -> sharded hidden"):  wq/wk/wv/w1/w3/in_proj ...
#   row-parallel   ("sharded hidden -> out"):  wo/w2/out_proj ...
#   expert-parallel: experts_* leading E dim
#   embeddings: vocab dim on model
# Stacked-layer params carry a leading L dim -> rules are right-aligned.
# For ZeRO/FSDP (giant MoE archs) `fsdp=True` additionally shards the largest
# replicated dim over the DP axes.

_PARAM_RULES: list[tuple[tuple[str, ...], tuple]] = [
    (("embed",),            ("model", None)),     # tied: unembed-side local
    (("embed_in",),         (None, "model")),     # untied input: local gather
    (("unembed",),          (None, "model")),
    (("experts_w1", "experts_w3"), ("expert", None, "model")),
    (("experts_w2",),       ("expert", "model", None)),
    (("wq", "wk", "wv", "w_qkv", "w1", "w3", "in_proj", "q_up", "k_up", "v_up",
      "w_gate_up", "conv_w", "w_ih"), (None, "model")),
    (("wo", "w2", "out_proj", "w_down"), ("model", None)),
    (("bq", "bk", "bv", "b1", "b3", "b_in"), ("model",)),
    (("q_down", "kv_down", "router", "w_hh"), (None, None)),
    (("a_log", "ssm_d", "dt_bias", "heads_scale"), ("model",)),
]


def infer_logical_axes(path: str, shape) -> tuple:
    """Logical axes for a param leaf, right-aligned to its shape."""
    leaf = path.split("/")[-1]
    rule = None
    for names, axes in _PARAM_RULES:
        if leaf in names:
            rule = axes
            break
    if rule is None:
        rule = (None,) * len(shape)
    if len(rule) < len(shape):                 # stacked-layer leading dims
        rule = (None,) * (len(shape) - len(rule)) + tuple(rule)
    elif len(rule) > len(shape):
        rule = tuple(rule[-len(shape):])
    return tuple(rule)


def tree_param_shardings(mesh: Mesh, params, fsdp: bool = False):
    """NamedSharding pytree for a param pytree, by name rules."""
    def one(kp, x):
        path = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in kp)
        axes = infer_logical_axes(path, x.shape)
        return param_sharding(mesh, axes, x.shape, fsdp=fsdp)
    return jax.tree_util.tree_map_with_path(one, params)

def param_sharding(mesh: Mesh, logical_axes, shape, fsdp: bool = False):
    spec = spec_for(mesh, logical_axes, shape)
    if fsdp:
        # shard the largest still-replicated dim over the DP axes (ZeRO-3).
        # Under pure_dp overrides the DP axes are the whole mesh.
        dp_axes = batch_axes(mesh)
        if dp_axes:
            used = {a for part in spec if part for a in
                    ((part,) if isinstance(part, str) else tuple(part))}
            if not any(a in used for a in dp_axes):
                dp_total = 1
                for a in dp_axes:
                    dp_total *= mesh.shape[a]
                # pick the largest dim divisible by the dp extent
                best, best_dim = None, 0
                for i, (part, dim) in enumerate(zip(spec, shape)):
                    if part is None and dim % dp_total == 0 and dim > best_dim:
                        best, best_dim = i, dim
                if best is not None:
                    parts = list(spec)
                    parts[best] = dp_axes if len(dp_axes) > 1 else dp_axes[0]
                    spec = P(*parts)
    return NamedSharding(mesh, spec)

"""Compiled step builders shared by the dry-run, trainer and server.

``make_train_step``: value_and_grad + AdamW update, with microbatch gradient
accumulation via ``lax.scan`` (cfg.grad_accum) — batches arrive with a
leading [accum] dim so no resharding is needed between microbatches, and the
f32 gradient accumulator inherits the (possibly ZeRO/FSDP-sharded) parameter
sharding.

``make_serve_step``: one decode step + greedy sampling — returns the next
token ids, not the [B, vocab] logits, so the step's output traffic is O(B).
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.models import scan_util
from repro.models.lm import ModelAPI
from repro.optim.adam import AdamW


def make_train_step(model: ModelAPI, opt: AdamW) -> Callable:
    cfg = model.cfg
    accum = max(cfg.grad_accum, 1)

    def train_step(params, opt_state, batch):
        """batch leaves: [accum, B/accum, ...]."""
        if accum == 1:
            mb = jax.tree_util.tree_map(lambda x: x[0], batch)
            loss, grads = jax.value_and_grad(model.loss)(params, mb)
        else:
            def body(carry, mb):
                loss_acc, g_acc = carry
                l, g = jax.value_and_grad(model.loss)(params, mb)
                g_acc = jax.tree_util.tree_map(
                    lambda a, gi: a + gi.astype(jnp.float32), g_acc, g)
                return (loss_acc + l, g_acc), None

            g0 = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss_sum, g_sum), _ = scan_util.scan(body, (jnp.zeros((), jnp.float32), g0),
                                                batch)
            loss = loss_sum / accum
            grads = jax.tree_util.tree_map(lambda g: g / accum, g_sum)
        params, opt_state = opt.update(grads, opt_state, params)
        return params, opt_state, loss

    return train_step


def make_serve_step(model: ModelAPI) -> Callable:
    def serve_step(params, tokens, state):
        """tokens [B, 1] -> (next_tokens [B, 1], new state)."""
        logits, new_state = model.decode_step(params, tokens, state)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        return nxt, new_state

    return serve_step


def make_prefill_step(model: ModelAPI) -> Callable:
    def prefill_step(params, tokens, state):
        """tokens [B, S_prompt] -> (next_tokens [B, 1], filled state)."""
        logits, new_state = model.prefill(params, tokens, state)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        return nxt, new_state

    return prefill_step


def add_accum_dim(cfg, structs):
    """[B, ...] batch structs -> [accum, B/accum, ...] (train_step layout)."""
    accum = max(cfg.grad_accum, 1)

    def one(sd):
        b = sd.shape[0]
        assert b % accum == 0, (b, accum)
        return jax.ShapeDtypeStruct((accum, b // accum) + tuple(sd.shape[1:]),
                                    sd.dtype)

    return jax.tree_util.tree_map(one, structs)

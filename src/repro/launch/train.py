"""LM training driver (deliverable b's end-to-end path for the LM zoo).

Wires together: config -> model -> sharded params -> AdamW -> TokenPipeline
-> jitted train_step (grad accum, remat) -> CheckpointManager.

Fault-tolerance story exercised here (DESIGN.md §4):
  * periodic atomic checkpoints carrying step + data-pipeline cursor;
  * ``--resume`` restarts from the newest checkpoint, and because the data
    pipeline is seed-deterministic by (epoch, step), the token stream
    continues bit-exact;
  * **elastic**: the checkpoint stores unsharded leaves; on load they are
    placed under the *current* mesh's shardings, so the same run can resume
    on a different device count (reshard-on-load).

On this CPU container the driver runs REDUCED configs (same code path as the
production mesh, 1 device); the production mesh path is exercised by the
dry-run.  ``examples/lm_pretrain.py`` calls ``train_loop`` directly.
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from pathlib import Path
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.data.tokens import SyntheticCorpus, TokenPipeline
from repro.launch import sharding as shlib
from repro.launch.specs import (batch_shardings, param_shardings,
                                train_batch_structs)
from repro.launch.steps import add_accum_dim, make_train_step
from repro.models.lm import get_model
from repro.optim.adam import AdamConfig, AdamW


@dataclasses.dataclass
class TrainReport:
    losses: list
    step_times: list
    resumed_from: int = 0
    checkpoints: int = 0


def _extra_builders(cfg) -> dict:
    """Stub-frontend embedding builders (audio/vlm) for the pipeline."""
    out = {}
    if cfg.encoder_layers > 0:
        def frames(epoch, step, accum, b_local, _cfg=cfg):
            rng = np.random.default_rng((epoch * 1_000_003 + step) * 2 + 1)
            from repro.models.lm import enc_dec_split
            return rng.standard_normal(
                (accum, b_local, 0, _cfg.d_model), dtype=np.float32)
        # seq dims are bound in train_loop where seq_len is known
    return out


def train_loop(cfg, *, steps: int, batch: int, seq_len: int,
               mesh=None, lr: float = 3e-4, seed: int = 0,
               ckpt_dir: Optional[str] = None, ckpt_every: int = 50,
               resume: bool = False, log_every: int = 10) -> TrainReport:
    model = get_model(cfg)
    opt = AdamW(AdamConfig(lr=lr, clip_norm=1.0))
    train_step = make_train_step(model, opt)
    accum = max(cfg.grad_accum, 1)

    with shlib.use_mesh(mesh):
        params = model.init(jax.random.PRNGKey(seed))
        opt_state = opt.init(params)
        if mesh is not None:
            p_sh = param_shardings(mesh, params, cfg)
            params = jax.tree_util.tree_map(jax.device_put, params, p_sh)
            opt_state = {
                "m": jax.tree_util.tree_map(jax.device_put, opt_state["m"], p_sh),
                "v": jax.tree_util.tree_map(jax.device_put, opt_state["v"], p_sh),
                "step": opt_state["step"],
            }
        step_fn = jax.jit(train_step, donate_argnums=(0, 1))

        mgr = CheckpointManager(ckpt_dir, every=ckpt_every) if ckpt_dir else None
        start = 0
        if mgr and resume:
            (params, opt_state), start, _extra = _restore(mgr, (params, opt_state))

        corpus = SyntheticCorpus(cfg.vocab_size, seed=seed)
        from repro.models.lm import enc_dec_split
        if cfg.encoder_layers > 0:
            s_enc, s_dec = enc_dec_split(cfg, seq_len)
            def frames(epoch, step, a, b, d=cfg.d_model, s=s_enc):
                rng = np.random.default_rng((epoch * 1_000_003 + step))
                return rng.standard_normal((a, b, s, d)).astype(np.float32)
            pipe = TokenPipeline(corpus, batch, s_dec, accum=accum,
                                 extra_builders={"frame_embeds": frames})
        elif cfg.frontend == "vision":
            p = min(cfg.frontend_tokens, max(seq_len - 1, 1))
            def patches(epoch, step, a, b, d=cfg.d_model, s=p):
                rng = np.random.default_rng((epoch * 1_000_003 + step))
                return rng.standard_normal((a, b, s, d)).astype(np.float32)
            pipe = TokenPipeline(corpus, batch, seq_len - p, accum=accum,
                                 extra_builders={"patch_embeds": patches})
        else:
            pipe = TokenPipeline(corpus, batch, seq_len, accum=accum)

        report = TrainReport([], [], resumed_from=start)
        for step, host_batch in enumerate(pipe.epoch(0, steps, start_step=start),
                                          start=start):
            t0 = time.perf_counter()
            dev_batch = jax.tree_util.tree_map(jnp.asarray, host_batch)
            params, opt_state, loss = step_fn(params, opt_state, dev_batch)
            loss = float(loss)
            report.losses.append(loss)
            report.step_times.append(time.perf_counter() - t0)
            if mgr:
                saved = mgr.maybe_save(step + 1, (params, opt_state),
                                       extra={"seq_len": seq_len, "batch": batch})
                if saved:
                    report.checkpoints += 1
            if log_every and step % log_every == 0:
                print(f"step {step}: loss {loss:.4f} "
                      f"({report.step_times[-1]*1e3:.0f} ms)", flush=True)
        return report


def _restore(mgr: CheckpointManager, tree_like):
    tree, step, extra = mgr.restore_or_init(tree_like)
    if step:
        tree = jax.tree_util.tree_map(jnp.asarray, tree)
    return tree, step, extra


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--reduced", action="store_true",
                    help="smoke-scale config (CPU container default)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    report = train_loop(cfg, steps=args.steps, batch=args.batch,
                        seq_len=args.seq_len, ckpt_dir=args.ckpt_dir,
                        resume=args.resume)
    print(f"final loss: {report.losses[-1]:.4f}  "
          f"mean step: {np.mean(report.step_times[1:]) * 1e3:.1f} ms")


if __name__ == "__main__":
    main()

"""Input / state / parameter specs for the dry-run and launchers.

``input_specs(cfg, shape)`` returns weak-type-correct ShapeDtypeStruct
stand-ins for every model input — shardable, no device allocation — the
pattern required by the multi-pod dry-run (system instructions §MULTI-POD).

Decode state sharding rules (leaf name + trailing-rank keyed; leading
stacked-layer dims replicate):

  k/v        [L,B,Hkv,S,Dh] -> (None, batch, model, seq, None)
  slot_pos   [L,W]          -> replicated (tiny)
  c_kv       [L,B,S,lora]   -> (None, batch, seq, None)     (MLA latent)
  k_rope     [L,B,S,rope]   -> (None, batch, seq, None)
  conv       [.,B,K,C]      -> (batch, None, model)          (Mamba2)
  ssd        [.,B,H,P,N]    -> (batch, model, None, None)
  mLSTM c/n/m, sLSTM h/c/n/m -> batch + heads-on-model

'batch' resolves to ('pod','data'); 'seq' to 'data' — each mesh axis is used
at most once per spec, so decode_32k (B=128) shards batch over pod×data and
replicates seq, while long_500k (B=1) shards the 500k-token cache over 'data'
(sequence parallelism) instead.  Axes that do not divide fall back to
replication (launch/sharding.py).
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding

from repro.configs.base import ArchConfig, ShapeSpec
from repro.launch.sharding import spec_for, tree_param_shardings
from repro.models.lm import ModelAPI, enc_dec_split, get_model


# ---------------------------------------------------------------------------
# batch structs
# ---------------------------------------------------------------------------

def train_batch_structs(cfg: ArchConfig, shape: ShapeSpec) -> dict:
    b, s = shape.global_batch, shape.seq_len
    if cfg.encoder_layers > 0:
        s_enc, s_dec = enc_dec_split(cfg, s)
        return {
            "frame_embeds": jax.ShapeDtypeStruct((b, s_enc, cfg.d_model),
                                                 jnp.float32),
            "tokens": jax.ShapeDtypeStruct((b, s_dec), jnp.int32),
        }
    if cfg.frontend == "vision":
        p = min(cfg.frontend_tokens, max(s - 1, 1))
        return {
            "patch_embeds": jax.ShapeDtypeStruct((b, p, cfg.d_model),
                                                 jnp.float32),
            "tokens": jax.ShapeDtypeStruct((b, s - p), jnp.int32),
        }
    return {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32)}


def batch_shardings(mesh: Mesh, structs: dict, accum_dim: bool = False) -> dict:
    """Batch leaves shard on the batch dim; a leading [accum] microbatch dim
    (train_step layout, launch/steps.py) is replicated."""
    out = {}
    for name, sd in structs.items():
        lead = (None,) if accum_dim else ()
        axes = lead + ("batch",) + (None,) * (len(sd.shape) - len(lead) - 1)
        out[name] = NamedSharding(mesh, spec_for(mesh, axes, sd.shape))
    return out


# ---------------------------------------------------------------------------
# decode state structs
# ---------------------------------------------------------------------------

def decode_state_structs(model: ModelAPI, shape: ShapeSpec):
    cfg = model.cfg
    b, s = shape.global_batch, shape.seq_len
    if cfg.encoder_layers > 0:
        enc_len, _ = enc_dec_split(cfg, s)
        return jax.eval_shape(lambda: model.decode_init(b, s, enc_len))
    if cfg.xlstm is not None:
        return jax.eval_shape(lambda: model.decode_init(b))
    return jax.eval_shape(lambda: model.decode_init(b, s))


# leaf-name -> trailing logical axes, right-aligned; leading stacked dims None
_STATE_RULES: dict[str, tuple] = {
    "k": ("batch", "model", "seq", None),
    "v": ("batch", "model", "seq", None),
    "c_kv": ("batch", "seq", None),
    "k_rope": ("batch", "seq", None),
    "conv": ("batch", None, "model"),
    "ssd": ("batch", "model", None, None),
}
# per-layer ranks of the xLSTM cell states (run-stacked leaves add 1):
_MLSTM_RULES = {"c": ("batch", "model", None, None),
                "n": ("batch", "model", None), "m": ("batch", "model")}
_SLSTM_RULES = {"h": ("batch", "model", None), "c": ("batch", "model", None),
                "n": ("batch", "model", None), "m": ("batch", "model", None)}


def _state_axes(path: str, shape) -> tuple:
    leaf = path.split("/")[-1]
    if "mlstm" in path:
        rule = _MLSTM_RULES.get(leaf)
    elif "slstm" in path:
        rule = _SLSTM_RULES.get(leaf)
    else:
        rule = _STATE_RULES.get(leaf)
    if rule is None or len(rule) > len(shape):
        return (None,) * len(shape)
    return (None,) * (len(shape) - len(rule)) + rule


def state_shardings(mesh: Mesh, state_structs) -> Any:
    def one(kp, sd):
        path = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in kp)
        axes = _state_axes(path, sd.shape)
        return NamedSharding(mesh, spec_for(mesh, axes, sd.shape))
    return jax.tree_util.tree_map_with_path(one, state_structs)


# ---------------------------------------------------------------------------
# params / optimizer
# ---------------------------------------------------------------------------

def param_structs(model: ModelAPI):
    return jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))


def param_shardings(mesh: Mesh, structs, cfg: ArchConfig):
    return tree_param_shardings(mesh, structs, fsdp=cfg.fsdp)


def opt_state_shardings(mesh: Mesh, opt_structs, params_shardings):
    """Adam moments follow their parameter's sharding; step replicated."""
    return {
        "m": params_shardings,
        "v": params_shardings,
        "step": NamedSharding(mesh, spec_for(mesh, (), ())),
    }


# ---------------------------------------------------------------------------
# top-level: everything the dry-run needs for one (arch x shape)
# ---------------------------------------------------------------------------

def input_specs(cfg: ArchConfig, shape: ShapeSpec, mesh: Mesh,
                model: Optional[ModelAPI] = None) -> dict:
    """Structs + shardings for one dry-run cell.

    kind == train:  {params, opt, batch} structs/shardings for train_step.
    kind == decode: {params, tokens, state} structs/shardings for serve_step.
    (prefill lowers the same loss forward as train without the update.)
    """
    model = model or get_model(cfg)
    p_structs = param_structs(model)
    p_sh = param_shardings(mesh, p_structs, cfg)
    out = {"params": (p_structs, p_sh)}

    if shape.kind in ("decode", "prefill"):
        if shape.kind == "decode":
            s_new = 1
        elif cfg.encoder_layers > 0:       # enc-dec: prompt = decoder share
            _, s_new = enc_dec_split(cfg, shape.seq_len)
        else:
            s_new = shape.seq_len
        t_struct = jax.ShapeDtypeStruct((shape.global_batch, s_new), jnp.int32)
        t_sh = NamedSharding(mesh, spec_for(mesh, ("batch", None),
                                            t_struct.shape))
        s_structs = decode_state_structs(model, shape)
        out["tokens"] = (t_struct, t_sh)
        out["state"] = (s_structs, state_shardings(mesh, s_structs))
    else:
        from repro.launch.steps import add_accum_dim
        b_structs = add_accum_dim(cfg, train_batch_structs(cfg, shape))
        out["batch"] = (b_structs, batch_shardings(mesh, b_structs,
                                                   accum_dim=True))
    return out

"""Streaming graph ingest: delta-CSR updates under live training/serving.

Production graphs mutate while the server answers queries (ROADMAP item 4).
This package applies edge/node deltas to the live structure WITHOUT pausing
anything, by riding the generation machinery the repo already trusts:

* :class:`DeltaBuffer` — thread-safe, bounded (``QueueFull``), seq-stamped
  staging log producers append to at any time (``engine.ingest()``);
* :func:`merge_delta_csr` — deterministic delta-CSR merge, bitwise-equal to
  a from-scratch rebuild, applied by ``FeatureStore._build`` at the next
  generation boundary — the atomic swap then publishes structure + features
  together, while in-flight batches stay pinned to the pre-merge
  generation;
* :class:`StreamConfig` (re-exported from ``repro.gns.config``) — the
  declarative knob block nested under ``EngineConfig.stream``.

The temporal-event replay scenario lives in ``repro.data.temporal``; the
serve-while-mutating benchmark in ``benchmarks/bench_stream.py``.
"""
from repro.gns.config import StreamConfig
from repro.stream.delta import DeltaBatch, DeltaBuffer
from repro.stream.merge import merge_delta_csr

__all__ = ["DeltaBatch", "DeltaBuffer", "StreamConfig", "merge_delta_csr"]

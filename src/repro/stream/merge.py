"""Deterministic delta-CSR merge: fold buffered deltas into a host CSR.

The contract that makes streaming ingest safe to serve through the existing
generation machinery is **rebuild equivalence**: for any delta batch,

    merge_delta_csr(g, batch)  ==  CSRGraph.from_edges(post-merge edge set)

bitwise — same ``indptr`` (int64), same ``indices`` (int32), same per-row
sorted order.  ``FeatureStore._build`` can then materialize the post-merge
structure (induced cache adjacency, eq.-11 probabilities, DeviceCacheAdj)
exactly as if the graph had been loaded that way, and the atomic generation
swap carries structure the same way it carries features.  The property suite
in tests/test_stream_merge.py pins the equivalence.

The merge itself never re-sorts the old edge set: both the existing CSR and
the effective delta are expressed as globally ascending ``row * V + col``
keys (rows are indptr-grouped, within-row indices sorted — the
``from_edges`` invariant), so deletions are a sorted-membership mask and
insertions are a positional scatter at ``searchsorted`` offsets —
O(E + Δ log E) instead of the O(E log E) full rebuild.

Delta semantics (matching :class:`~repro.stream.delta.DeltaBuffer`):

* ops apply in **sequence order**; the last op on an edge key wins, so
  delete-then-insert inside one batch lands inserted, insert-then-delete
  lands absent;
* with ``symmetrize`` each op mirrors to both directions (the undirected
  convention of ``CSRGraph.from_edges``);
* self-loops are dropped, duplicate inserts of an existing edge are no-ops
  (idempotent), deletes of absent edges are no-ops;
* new nodes extend the id space by ``batch.num_new_nodes`` empty rows;
  every id referenced by an op must be below the post-merge node count.
"""
from __future__ import annotations

import numpy as np

from repro.graph.csr import CSRGraph


def _effective_ops(batch, num_nodes: int, symmetrize: bool
                   ) -> tuple[np.ndarray, np.ndarray]:
    """Collapse the op log to (sorted unique edge keys, winning op per key).

    Keys are ``src * num_nodes + dst`` in the POST-merge id space.  The
    winner per key is the op with the highest sequence number (mirrored ops
    share their original's seq — both directions of one logical op always
    agree, so the tie is harmless).
    """
    src = np.asarray(batch.edge_src, dtype=np.int64)
    dst = np.asarray(batch.edge_dst, dtype=np.int64)
    op = np.asarray(batch.edge_op, dtype=np.int8)
    seq = np.asarray(batch.edge_seq, dtype=np.int64)
    if symmetrize:
        src, dst = np.concatenate([src, dst]), np.concatenate([dst, src])
        op, seq = np.concatenate([op, op]), np.concatenate([seq, seq])
    keep = src != dst
    src, dst, op, seq = src[keep], dst[keep], op[keep], seq[keep]
    if not len(src):
        return np.zeros(0, np.int64), np.zeros(0, np.int8)
    assert int(src.max()) < num_nodes and int(dst.max()) < num_nodes, (
        "delta op references a node id beyond the post-merge id space — "
        "stage new nodes through DeltaBuffer.add_nodes first")
    assert int(src.min()) >= 0 and int(dst.min()) >= 0
    key = src * num_nodes + dst
    order = np.lexsort((seq, key))          # grouped by key, seq ascending
    key, op = key[order], op[order]
    last = np.ones(len(key), dtype=bool)    # last occurrence per key group
    last[:-1] = key[1:] != key[:-1]
    return key[last], op[last]


def merge_delta_csr(graph: CSRGraph, batch, *,
                    symmetrize: bool = True) -> CSRGraph:
    """Apply one drained :class:`~repro.stream.delta.DeltaBatch` to ``graph``.

    Returns a NEW :class:`CSRGraph` over ``graph.num_nodes +
    batch.num_new_nodes`` ids, bitwise-equal to rebuilding from the
    post-merge edge set (module docstring).  The input graph is never
    mutated — generations pinned to it keep sampling it unchanged.
    """
    v_new = graph.num_nodes + int(batch.num_new_nodes)
    eff_key, eff_op = _effective_ops(batch, v_new, symmetrize)

    # existing edges as globally ascending keys in the NEW id space (row
    # blocks are indptr-ordered and within-row sorted, so the flattened key
    # sequence is strictly increasing — no sort needed)
    row_of_edge = np.repeat(np.arange(graph.num_nodes, dtype=np.int64),
                            graph.degrees)
    old_keys = row_of_edge * v_new + graph.indices.astype(np.int64)

    del_keys = eff_key[eff_op < 0]
    if len(del_keys):
        # sorted-membership mask: an old edge survives unless deleted
        pos = np.searchsorted(del_keys, old_keys)
        pos = np.minimum(pos, len(del_keys) - 1)
        kept = old_keys[del_keys[pos] != old_keys]
    else:
        kept = old_keys

    ins_keys = eff_key[eff_op > 0]
    if len(ins_keys) and len(kept):
        # idempotence: inserting an edge that already exists is a no-op
        pos = np.searchsorted(kept, ins_keys)
        pos = np.minimum(pos, len(kept) - 1)
        ins_keys = ins_keys[kept[pos] != ins_keys]
    if len(ins_keys):
        # positional scatter: both sides sorted, so the merged key sequence
        # is the sorted union without a global re-sort
        at = np.searchsorted(kept, ins_keys) + np.arange(len(ins_keys))
        merged = np.empty(len(kept) + len(ins_keys), dtype=np.int64)
        new_slot = np.zeros(len(merged), dtype=bool)
        new_slot[at] = True
        merged[new_slot] = ins_keys
        merged[~new_slot] = kept
    else:
        merged = kept

    indptr = np.zeros(v_new + 1, dtype=np.int64)
    np.add.at(indptr, merged // v_new + 1, 1)
    np.cumsum(indptr, out=indptr)
    return CSRGraph(indptr=indptr,
                    indices=(merged % v_new).astype(np.int32))

"""DeltaBuffer — thread-safe staging log for streaming graph mutations.

The ingest half of the streaming subsystem: producers (request handlers, the
temporal-event replay, ``engine.ingest()``) append edge insertions/deletions
and new-node feature rows HERE, concurrently with training and serving; the
:class:`~repro.featurestore.FeatureStore` drains the buffer exactly once per
generation build and folds the drained :class:`DeltaBatch` into the host CSR
(:func:`~repro.stream.merge.merge_delta_csr`) before scoring/drawing the new
generation — so structure changes only ever publish through the atomic swap.

Discipline mirrors the serving tier:

* **bounded admission** — ops staged beyond ``max_pending`` raise
  :class:`~repro.serve.server.QueueFull` (same exception class, so callers
  reuse one backpressure handler);
* **monotonic sequence numbers** — every edge op gets the next ``seq``;
  the merge resolves conflicting ops on one edge by highest seq
  (last-op-wins), and ``DeltaBatch.first_seq``/``last_seq`` give drains a
  total order;
* **`@guarded_by` annotations** — the same machine-checked lock contract
  as the store/server (gnscheck static pass + the runtime sanitizer).

New nodes: :meth:`add_nodes` allocates the next contiguous id range (the
post-merge id space grows by exactly the staged rows) and stages their
feature/label rows; edges may reference the new ids immediately — they
become queryable once the merge publishes.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import List, Optional

import numpy as np

from repro.analysis import guarded_by, holds_lock


@dataclasses.dataclass(frozen=True)
class DeltaBatch:
    """One drained, immutable slice of the op log (the merge input)."""
    edge_src: np.ndarray            # int64 [n_ops]
    edge_dst: np.ndarray            # int64 [n_ops]
    edge_op: np.ndarray             # int8 [n_ops]  +1 insert | -1 delete
    edge_seq: np.ndarray            # int64 [n_ops] monotonic
    node_feats: Optional[np.ndarray]    # f32 [n_new, F] | None
    node_labels: Optional[np.ndarray]   # int64 [n_new] | None
    node_base: int                  # first new node id (== pre-merge V)
    first_seq: int
    last_seq: int

    @property
    def num_ops(self) -> int:
        return len(self.edge_src)

    @property
    def num_new_nodes(self) -> int:
        return 0 if self.node_feats is None else len(self.node_feats)

    @property
    def payload_bytes(self) -> int:
        """Staged bytes this batch carries across the ingest boundary
        (``TrafficMeter.bytes_delta_upload``)."""
        n = (self.edge_src.nbytes + self.edge_dst.nbytes
             + self.edge_op.nbytes + self.edge_seq.nbytes)
        if self.node_feats is not None:
            n += self.node_feats.nbytes
        if self.node_labels is not None:
            n += self.node_labels.nbytes
        return int(n)


@guarded_by("_lock", "_src", "_dst", "_op", "_seq", "_feats", "_labels",
            "_next_node", "_next_seq", "_pending",
            writes_only=("admitted", "rejected", "drains"))
class DeltaBuffer:
    """Bounded, seq-stamped staging log of graph deltas (module docstring)."""

    def __init__(self, num_nodes: int, feat_dim: int, *,
                 max_pending: int = 4096):
        self.max_pending = int(max_pending)
        self.feat_dim = int(feat_dim)
        self._lock = threading.Lock()
        self._src: List[np.ndarray] = []
        self._dst: List[np.ndarray] = []
        self._op: List[np.ndarray] = []
        self._seq: List[np.ndarray] = []
        self._feats: List[np.ndarray] = []
        self._labels: List[np.ndarray] = []
        self._next_node = int(num_nodes)    # post-merge id space high-water
        self._next_seq = 0
        self._pending = 0                   # staged ops + staged node rows
        self.admitted = 0
        self.rejected = 0
        self.drains = 0

    # ------------------------------------------------------------------
    @holds_lock("_lock")
    def _admit_locked(self, n: int) -> None:
        # lazy import: repro.serve's package __init__ pulls repro.gns.config,
        # which must stay importable while repro.stream is mid-import
        from repro.serve.server import QueueFull
        if self._pending + n > self.max_pending:
            self.rejected += n
            raise QueueFull(
                f"delta buffer at capacity ({self._pending}/"
                f"{self.max_pending} staged ops): merge a generation before "
                f"ingesting more")

    def _stage_edges(self, src, dst, op: int) -> int:
        src = np.atleast_1d(np.asarray(src, dtype=np.int64))
        dst = np.atleast_1d(np.asarray(dst, dtype=np.int64))
        assert src.shape == dst.shape and src.ndim == 1, (src.shape, dst.shape)
        n = len(src)
        if n == 0:
            with self._lock:
                return self._next_seq
        with self._lock:
            self._admit_locked(n)
            hi = max(int(src.max()), int(dst.max()))
            lo = min(int(src.min()), int(dst.min()))
            assert 0 <= lo and hi < self._next_node, (
                f"edge op references node {hi} outside the staged id space "
                f"[0, {self._next_node}) — add_nodes first")
            first = self._next_seq
            self._src.append(src)
            self._dst.append(dst)
            self._op.append(np.full(n, op, dtype=np.int8))
            self._seq.append(np.arange(first, first + n, dtype=np.int64))
            self._next_seq = first + n
            self._pending += n
            self.admitted += n
        return first

    # ------------------------------------------------------------------
    # producer API
    # ------------------------------------------------------------------
    def add_edges(self, src, dst) -> int:
        """Stage edge insertions; returns the first assigned seq."""
        return self._stage_edges(src, dst, +1)

    def delete_edges(self, src, dst) -> int:
        """Stage edge deletions; returns the first assigned seq."""
        return self._stage_edges(src, dst, -1)

    def add_nodes(self, feats: np.ndarray,
                  labels: Optional[np.ndarray] = None) -> np.ndarray:
        """Stage new nodes with their feature rows; returns their ids.

        Ids are allocated contiguously from the current post-merge id
        space, so staged edges may reference them immediately; the rows
        land in the feature/label tiers at the next merge.
        """
        feats = np.asarray(feats, dtype=np.float32)
        if feats.ndim == 1:
            feats = feats[None, :]
        assert feats.shape[1] == self.feat_dim, (feats.shape, self.feat_dim)
        n = len(feats)
        if labels is not None:
            labels = np.atleast_1d(np.asarray(labels, dtype=np.int64))
            assert len(labels) == n, (len(labels), n)
        with self._lock:
            self._admit_locked(n)
            base = self._next_node
            self._feats.append(feats)
            self._labels.append(labels if labels is not None
                                else np.zeros(n, dtype=np.int64))
            self._next_node = base + n
            self._pending += n
            self.admitted += n
        return np.arange(base, base + n, dtype=np.int64)

    # ------------------------------------------------------------------
    # consumer API (the store's generation build)
    # ------------------------------------------------------------------
    def pending(self) -> int:
        """Staged ops + node rows awaiting a merge."""
        with self._lock:
            return self._pending

    @property
    def next_node(self) -> int:
        """The post-merge node-id high-water mark (pre-merge V + staged)."""
        with self._lock:
            return self._next_node

    # ------------------------------------------------------------------
    # checkpoint surface (repro.checkpoint aux payload)
    # ------------------------------------------------------------------
    def state(self) -> dict:
        """Snapshot the staged-but-unmerged log for checkpointing.

        Returns a dict of flat numpy arrays plus the id/seq high-water
        marks — exactly what :meth:`restore` consumes.  The snapshot is
        taken atomically, so a save that races with producers captures a
        consistent seq prefix.
        """
        with self._lock:
            feats = (np.concatenate(self._feats) if self._feats
                     else np.zeros((0, self.feat_dim), np.float32))
            labels = (np.concatenate(self._labels) if self._labels
                      else np.zeros(0, np.int64))
            return {
                "edge_src": (np.concatenate(self._src) if self._src
                             else np.zeros(0, np.int64)),
                "edge_dst": (np.concatenate(self._dst) if self._dst
                             else np.zeros(0, np.int64)),
                "edge_op": (np.concatenate(self._op) if self._op
                            else np.zeros(0, np.int8)),
                "edge_seq": (np.concatenate(self._seq) if self._seq
                             else np.zeros(0, np.int64)),
                "node_feats": feats,
                "node_labels": labels,
                "next_node": np.int64(self._next_node),
                "next_seq": np.int64(self._next_seq),
            }

    def restore(self, state: dict) -> None:
        """Adopt a checkpointed staging log (inverse of :meth:`state`).

        REPLACES whatever is currently staged — restore-then-restore is a
        no-op (idempotent), and replaying a snapshot whose ops were already
        merged is safe because the merge resolves per-edge conflicts by
        highest seq (last-op-wins): re-applied ops carry their original
        seqs, so they can never override anything staged after them.
        """
        src = np.asarray(state["edge_src"], dtype=np.int64)
        dst = np.asarray(state["edge_dst"], dtype=np.int64)
        op = np.asarray(state["edge_op"], dtype=np.int8)
        seq = np.asarray(state["edge_seq"], dtype=np.int64)
        feats = np.asarray(state["node_feats"], dtype=np.float32)
        labels = np.asarray(state["node_labels"], dtype=np.int64)
        assert src.shape == dst.shape == op.shape == seq.shape, (
            src.shape, dst.shape, op.shape, seq.shape)
        assert feats.ndim == 2 and feats.shape[1] == self.feat_dim, (
            feats.shape, self.feat_dim)
        next_seq = int(state["next_seq"])
        next_node = int(state["next_node"])
        if len(seq):
            assert next_seq > int(seq.max()), (next_seq, int(seq.max()))
        with self._lock:
            self._src = [src] if len(src) else []
            self._dst = [dst] if len(dst) else []
            self._op = [op] if len(op) else []
            self._seq = [seq] if len(seq) else []
            self._feats = [feats] if len(feats) else []
            self._labels = [labels] if len(feats) else []
            # never rewind the seq/id clocks: a snapshot older than what
            # this buffer already handed out must not recycle seqs (the
            # last-op-wins guarantee depends on monotonicity)
            self._next_seq = max(self._next_seq, next_seq)
            self._next_node = max(self._next_node, next_node)
            self._pending = int(len(src) + len(feats))

    def drain(self) -> Optional[DeltaBatch]:
        """Atomically take everything staged (None when empty).

        The drained batch is immutable and seq-ordered; producers staging
        after the drain land in the NEXT batch/generation.
        """
        with self._lock:
            if self._pending == 0:
                return None
            src = (np.concatenate(self._src) if self._src
                   else np.zeros(0, np.int64))
            dst = (np.concatenate(self._dst) if self._dst
                   else np.zeros(0, np.int64))
            op = (np.concatenate(self._op) if self._op
                  else np.zeros(0, np.int8))
            seq = (np.concatenate(self._seq) if self._seq
                   else np.zeros(0, np.int64))
            feats = (np.concatenate(self._feats) if self._feats else None)
            labels = (np.concatenate(self._labels) if self._feats else None)
            n_new = 0 if feats is None else len(feats)
            batch = DeltaBatch(
                edge_src=src, edge_dst=dst, edge_op=op, edge_seq=seq,
                node_feats=feats, node_labels=labels,
                node_base=self._next_node - n_new,
                first_seq=int(seq[0]) if len(seq) else self._next_seq,
                last_seq=int(seq[-1]) if len(seq) else self._next_seq)
            self._src, self._dst, self._op, self._seq = [], [], [], []
            self._feats, self._labels = [], []
            self._pending = 0
            self.drains += 1
        return batch
